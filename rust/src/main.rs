//! `truedepth` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   info                         manifest + checkpoint inventory
//!   verify    [--artifacts DIR] [--strict]
//!                                static plan/binding/collective check of the
//!                                artifact manifest (prints every diagnostic;
//!                                --strict also requires artifact files on
//!                                disk and promotes warnings to errors)
//!   generate  --model M --prompt P [--depth D] [--max-new N] [--no-simnet]
//!   ppl       --model M [--transform T --s S --e E]
//!   serve     --model M [--depth D | --tiers] [--config run.toml]
//!             [--max-cached-execs N] --requests N
//!             [--paged [--page-pool N]]
//!             [--trace-out F] [--metrics-out F]
//!             [--listen ADDR [--replicas R] [--http-workers N]
//!              [--http-backlog N]]
//!                                synthetic load demo; --tiers serves every
//!                                manifest plan variant concurrently
//!                                (requests cycle dense/lp/lp_aggr).
//!                                --paged serves from the paged KV cache and
//!                                prefixes every request with one shared
//!                                system prompt, so the prefix index prefills
//!                                it once (kv.* section in the snapshot);
//!                                --page-pool caps the logical page pools to
//!                                model memory pressure.
//!                                --config applies a RunConfig TOML
//!                                ([interconnect]/[device] cost model +
//!                                [runtime] max_cached_execs); the CLI flag
//!                                overrides the [runtime] knob.
//!                                --trace-out writes a Chrome/Perfetto trace
//!                                of the run on the simulated clock;
//!                                --metrics-out writes a machine-readable
//!                                metrics snapshot (both deterministic; see
//!                                README "Observability")
//!                                --listen ADDR serves the HTTP API instead
//!                                of synthetic load: POST /v1/completions
//!                                (SSE streaming via "stream": true),
//!                                GET /v1/models, GET /healthz, GET /metrics,
//!                                POST /admin/shutdown (see docs/api.md);
//!                                --replicas R fronts R independent replicas
//!                                behind the cluster cost-model router
//!                                (session affinity via the request's
//!                                "session" key; see README "Cluster
//!                                serving")
//!   loadtest  --model M --replicas R --seed S --requests N
//!             [--scenario steady|bursty|multiturn|flood|mixed]
//!             [--queue-depth D] [--paged [--page-pool N]]
//!             [--fail-replica I --fail-at-step T [--respawn-at-step T2]]
//!             [--metrics-out F] [--trace-out F] [--arrivals-out F]
//!                                deterministic trace-driven cluster load
//!                                harness: expands (scenario, seed) into a
//!                                replayable arrival schedule, replays it
//!                                against an R-replica lockstep cluster
//!                                (seeded weights — no checkpoint needed),
//!                                optionally fencing/respawning a replica
//!                                mid-run, and exits non-zero on any lost,
//!                                failed or shed request. Exports are
//!                                byte-identical across runs for one seed:
//!                                --metrics-out (cluster snapshot),
//!                                --trace-out (per-replica Chrome traces,
//!                                <stem>.rN.json), --arrivals-out (the
//!                                schedule as truedepth.loadtrace/v1 JSON)
//!   apidoc                       print docs/api.md, generated from the
//!                                api:: schema (regenerate after API edits)
//!
//! Examples live in `examples/` (quickstart, serve_batch, depth_explorer);
//! experiment regenerators in `rust/src/bin/` (see DESIGN.md).

use truedepth::api::CompletionRequest;
use truedepth::cli::Args;
use truedepth::config::ServerConfig;
use truedepth::coordinator::Server;
use truedepth::eval::ppl::{eval_windows, perplexity};
use truedepth::gen::{generate, Sampler};
use truedepth::harness::{default_net, no_net, ScoringCtx};
use truedepth::model::{transform, Scorer, ServingModel};
use truedepth::obs::{MetricsSnapshot, Tracer};
use truedepth::text::corpus::{self, DATA_SEED};
use truedepth::util::rng::SplitMix64;

fn main() {
    let args = Args::from_env(&["no-simnet", "tiers", "strict", "paged", "help"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "info" => info(),
        "verify" => cmd_verify(&args),
        "generate" => cmd_generate(&args),
        "ppl" => cmd_ppl(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "apidoc" => {
            print!("{}", truedepth::api::docs::render_api_md());
            Ok(())
        }
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

const HELP: &str = "truedepth — Layer Parallelism for LLM inference
usage: truedepth <info|verify|generate|ppl|serve|loadtest|apidoc> [options]   (see src/main.rs docs)";

fn cmd_verify(args: &Args) -> truedepth::Result<()> {
    let dir = match args.get("artifacts") {
        Some(p) => std::path::PathBuf::from(p),
        None => truedepth::repo_root().join("artifacts"),
    };
    truedepth::verify::run_cli(&dir, args.flag("strict"))
}

fn info() -> truedepth::Result<()> {
    let manifest = truedepth::runtime::Manifest::load_default()?;
    println!("artifacts: {} (impl: {})", manifest.dir.display(), manifest.impl_name);
    println!("seq buckets: {:?}", manifest.seq_buckets);
    for (name, entry) in &manifest.models {
        let c = &entry.config;
        let ckpt = truedepth::repo_root().join("checkpoints").join(name).join("weights.tdw");
        println!(
            "model {name}: {} layers, d={}, heads={}, ~{:.1}M params, {} artifacts, checkpoint: {}",
            c.n_layers,
            c.d_model,
            c.n_heads,
            c.n_params() as f64 / 1e6,
            entry.artifacts.len(),
            if ckpt.exists() { "yes" } else { "no (run `make models`)" }
        );
    }
    Ok(())
}

fn plan_for(args: &Args, n: usize) -> truedepth::Result<truedepth::model::GraphPlan> {
    let depth = args.get_usize("depth", n);
    if depth == n {
        return Ok(transform::sequential(n));
    }
    transform::lp_for_depth(n, depth, args.get_usize("end", n - 2))
        .ok_or_else(|| truedepth::Error::msg(format!("no LP window for depth {depth}")))
}

fn cmd_generate(args: &Args) -> truedepth::Result<()> {
    let model = args.get_or("model", "td-small");
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let n = ctx.entry().config.n_layers;
    let plan = plan_for(args, n)?;
    let net = if args.flag("no-simnet") { no_net() } else { default_net() };
    let serving = ServingModel::new(&ctx.manifest, model, &weights, &plan, net)?;
    let prompt = args.get_or("prompt", "the capital of avaria is");
    let g = generate(&serving, prompt, args.get_usize("max-new", 32), &Sampler::Greedy)?;
    println!("plan: {} (depth {})", plan.describe(), plan.effective_depth());
    println!("prompt: {prompt}");
    println!("output: {}", g.text);
    println!(
        "prefill {:.1} ms, decode {:.1} ms ({:.1} tok/s)",
        g.prefill_ms,
        g.decode_ms,
        g.tokens.len() as f64 / (g.decode_ms / 1e3)
    );
    Ok(())
}

fn cmd_ppl(args: &Args) -> truedepth::Result<()> {
    let model = args.get_or("model", "td-small");
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let (s, e) = (args.get_usize("s", 0), args.get_usize("e", 0));
    let plan = match args.get_or("transform", "seq") {
        "seq" => transform::sequential(n),
        "shuffle" => {
            let mut rng = SplitMix64::new(1);
            transform::shuffle(n, s, e, &mut rng)
        }
        "prune" => transform::prune(n, s, e),
        "merge" => transform::merge(n, s, e),
        "parallel" => transform::parallel(n, s, e),
        "pair" => transform::pair_parallel(n, s, e, true),
        other => return Err(truedepth::Error::msg(format!("unknown transform {other}"))),
    };
    let scorer = Scorer::new(&ctx.engine, entry, &weights, 128)?;
    let windows = eval_windows(128, args.get_usize("windows", 2), DATA_SEED);
    let ppl = perplexity(&scorer, &plan, &windows)?;
    println!("plan: {} (depth {})", plan.describe(), plan.effective_depth());
    println!("perplexity: {ppl:.4}");
    Ok(())
}

/// The interconnect cost model the flags select: `--config` wins, then
/// `--no-simnet` zeroes the α–β term, else the calibrated defaults.
fn cost_net(
    args: &Args,
    run_cfg: &truedepth::config::RunConfig,
) -> truedepth::config::InterconnectConfig {
    let mut net = if args.get("config").is_some() {
        run_cfg.interconnect.clone()
    } else if args.flag("no-simnet") {
        no_net()
    } else {
        default_net()
    };
    if args.flag("no-simnet") {
        net.enabled = false;
    }
    net
}

/// `--trace-out F` with R replicas writes one Chrome trace per replica:
/// `<stem>.rN.json` next to F.
fn replica_trace_path(out: &std::path::Path, i: usize) -> std::path::PathBuf {
    let base = out.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    out.with_file_name(format!("{base}.r{i}.json"))
}

fn cmd_serve(args: &Args) -> truedepth::Result<()> {
    let replicas = args.get_usize("replicas", 1);
    if let Some(listen) = args.get("listen") {
        if replicas > 1 {
            return cmd_serve_cluster(args, listen, replicas);
        }
    }
    let model = args.get_or("model", "td-small");
    let n_requests = args.get_usize("requests", 12);
    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let n = ctx.entry().config.n_layers;
    // --config: a RunConfig TOML supplies the cost model ([interconnect] +
    // [device]) and the [runtime] section; without it the calibrated
    // defaults apply (--no-simnet still disables the α–β term either way).
    let run_cfg = match args.get("config") {
        Some(p) => truedepth::config::RunConfig::from_file(std::path::Path::new(p))?,
        None => truedepth::config::RunConfig::default(),
    };
    let cost =
        truedepth::parallel::CostModel::new(cost_net(args, &run_cfg), run_cfg.device.clone());
    // --tiers: one resident weight set, every manifest plan variant served
    // concurrently (the plan-variant registry); default: one --depth plan.
    let multi = args.flag("tiers");
    let mut serving = if multi {
        ServingModel::from_manifest_with_cost(&ctx.manifest, model, &weights, cost)?
    } else {
        let plan = plan_for(args, n)?;
        ServingModel::new_with_cost(&ctx.manifest, model, &weights, &plan, cost)?
    };
    // --paged: serve from the paged KV cache (+ shared-prefix index);
    // --page-pool shrinks the logical pools to model memory pressure —
    // over-pool requests are rejected at admission, cold shared blocks
    // are evicted under load.
    let paged = args.flag("paged");
    if paged {
        serving.enable_paging()?;
        let pool = args.get_usize("page-pool", 0);
        if pool > 0 {
            serving.set_page_capacity(pool);
        }
    }
    // `[runtime] max_cached_execs` (CLI flag overrides the config file;
    // 0 / absent = unbounded): LRU-evict compiled executables beyond the
    // cap, recompiling transparently on reuse.
    let cap = match args.get_usize("max-cached-execs", 0) {
        0 => run_cfg.runtime.max_cached_execs,
        c => Some(c),
    };
    serving.set_exec_cache_cap(cap);
    let tiers: Vec<String> =
        serving.variant_ids().iter().map(|v| v.as_str().to_string()).collect();
    let default_tier = serving.default_tier().to_string();
    let depths: Vec<String> = serving
        .variant_ids()
        .iter()
        .map(|v| format!("{v}:{}", serving.variant(v).unwrap().effective_depth()))
        .collect();
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    let tracer = trace_out.as_ref().map(|_| std::sync::Arc::new(Tracer::new()));
    let server = std::sync::Arc::new(match &tracer {
        Some(t) => Server::start_traced(serving, &ServerConfig::default(), t.clone()),
        None => Server::start(serving, &ServerConfig::default()),
    });
    let metrics = server.metrics.clone();

    if let Some(listen) = args.get("listen") {
        // network mode: serve the HTTP API until POST /admin/shutdown
        let cfg = truedepth::serve::HttpConfig {
            workers: args.get_usize("http-workers", 4),
            backlog: args.get_usize("http-backlog", 16),
        };
        let models = truedepth::api::ModelsResponse {
            models: vec![truedepth::api::ModelInfo {
                model: model.to_string(),
                tiers: tiers.clone(),
                default_tier: default_tier.clone(),
            }],
            replicas: 1,
        };
        let backend =
            std::sync::Arc::new(truedepth::serve::SingleBackend::new(server.clone(), models));
        let edge = truedepth::serve::serve(backend, listen, &cfg)?;
        println!(
            "serving {model} [{}] on http://{} — POST /v1/completions (docs/api.md)",
            depths.join(" "),
            edge.local_addr()
        );
        edge.wait();
        println!("{}", metrics.report());
    } else {
        println!(
            "serving {model} [{}] — {n_requests} synthetic requests",
            depths.join(" ")
        );
        let t0 = std::time::Instant::now();
        // --paged load: every request carries the same system prompt ahead
        // of its own document snippet, so the shared-prefix index prefills
        // those leading blocks once and every later request attaches them —
        // the reuse shows up as kv.prefix_hits in the report and snapshot.
        const SYSTEM_PROMPT: &str = "system: you are a terse assistant. answer only from the \
             provided context, cite sources, never speculate. ";
        let handles: Vec<_> = (0..n_requests)
            .map(|i| {
                let doc = corpus::eval_doc(DATA_SEED, 1000 + i as u64);
                let snippet = &doc[..doc.len().min(if paged { 16 } else { 48 })];
                let prompt = if paged {
                    format!("{SYSTEM_PROMPT}{snippet}")
                } else {
                    snippet.to_string()
                };
                let mut req = CompletionRequest::new(prompt).max_tokens(16);
                if multi {
                    req = req.tier(&tiers[i % tiers.len()]);
                }
                server.request(req)
            })
            .collect::<truedepth::Result<_>>()?;
        let mut total_tokens = 0;
        for h in handles {
            total_tokens += h.wait()?.generated_tokens();
        }
        let wall = t0.elapsed().as_secs_f64();
        println!("{}", metrics.report());
        println!(
            "throughput: {:.1} generated tok/s ({total_tokens} tokens / {wall:.2}s)",
            total_tokens as f64 / wall
        );
    }
    // dropping the last handle drains the scheduler, which flushes the
    // mesh event track into the tracer — export only after it returns
    drop(server);
    if let (Some(tr), Some(path)) = (&tracer, &trace_out) {
        tr.write_chrome(path)?;
        println!("trace: {} ({} events)", path.display(), tr.len());
    }
    if let Some(path) = &metrics_out {
        MetricsSnapshot::new("serve").with_server(&metrics).write(path)?;
        println!("metrics snapshot: {}", path.display());
    }
    Ok(())
}

/// `serve --listen --replicas R`: R independent replicas (each its own
/// mesh, scheduler and KV cache) behind the cluster cost-model router,
/// fronted by the same HTTP edge. A driver thread ticks the lockstep
/// cluster; the edge submits into it through `serve::ClusterBackend`.
/// Requests carrying a `"session"` key pin to one replica so multi-turn
/// paged-KV prefix reuse stays local (README "Cluster serving").
fn cmd_serve_cluster(args: &Args, listen: &str, replicas: usize) -> truedepth::Result<()> {
    let model = args.get_or("model", "td-small").to_string();
    let run_cfg = match args.get("config") {
        Some(p) => truedepth::config::RunConfig::from_file(std::path::Path::new(p))?,
        None => truedepth::config::RunConfig::default(),
    };
    let net = cost_net(args, &run_cfg);
    let device = run_cfg.device.clone();
    let multi = args.flag("tiers");
    // probe once so --depth resolves against the layer count (and bad
    // flags fail before R weight loads); the factory then reloads the
    // checkpoint per replica — and again on every respawn
    let probe = ScoringCtx::load(&model)?;
    let n = probe.entry().config.n_layers;
    let plan = if multi { None } else { Some(plan_for(args, n)?) };
    drop(probe);
    let paged = args.flag("paged");
    let pool = args.get_usize("page-pool", 0);
    let cap = match args.get_usize("max-cached-execs", 0) {
        0 => run_cfg.runtime.max_cached_execs,
        c => Some(c),
    };
    let model_name = model.clone();
    let factory: truedepth::cluster::ModelFactory = Box::new(move |_i| {
        let ctx = ScoringCtx::load(&model_name)?;
        let weights = ctx.weights()?;
        let cost = truedepth::parallel::CostModel::new(net.clone(), device.clone());
        let mut serving = match &plan {
            None => ServingModel::from_manifest_with_cost(
                &ctx.manifest,
                &model_name,
                &weights,
                cost,
            )?,
            Some(p) => {
                ServingModel::new_with_cost(&ctx.manifest, &model_name, &weights, p, cost)?
            }
        };
        if paged {
            serving.enable_paging()?;
            if pool > 0 {
                serving.set_page_capacity(pool);
            }
        }
        serving.set_exec_cache_cap(cap);
        Ok(serving)
    });
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    let tracers = trace_out
        .as_ref()
        .map(|_| (0..replicas).map(|_| std::sync::Arc::new(Tracer::new())).collect::<Vec<_>>());
    let queue_depth = ServerConfig::default().queue_depth;
    let cluster = truedepth::cluster::Cluster::with_tracers(
        &model,
        factory,
        replicas,
        queue_depth,
        tracers.clone(),
    )?;
    let tiers = cluster.models_response().models[0].tiers.join(" ");
    let backend = std::sync::Arc::new(truedepth::serve::ClusterBackend::start(cluster));
    let cfg = truedepth::serve::HttpConfig {
        workers: args.get_usize("http-workers", 4),
        backlog: args.get_usize("http-backlog", 16),
    };
    let edge = truedepth::serve::serve(backend.clone(), listen, &cfg)?;
    println!(
        "serving {model} x{replicas} replicas [{tiers}] on http://{} — POST /v1/completions \
         (docs/api.md)",
        edge.local_addr()
    );
    edge.wait();
    // drain in-flight work and stop the driver thread, then export on the
    // quiesced cluster
    backend.shutdown();
    let cluster = backend.cluster();
    let c = cluster.lock().unwrap();
    c.finish();
    println!("{}", c.metrics.report());
    if let (Some(trs), Some(path)) = (&tracers, &trace_out) {
        for (i, tr) in trs.iter().enumerate() {
            let p = replica_trace_path(path, i);
            tr.write_chrome(&p)?;
            println!("trace: {} ({} events)", p.display(), tr.len());
        }
    }
    if let Some(path) = &metrics_out {
        c.snapshot("serve").write(path)?;
        println!("metrics snapshot: {}", path.display());
    }
    Ok(())
}

/// `truedepth loadtest`: the deterministic trace-driven cluster load
/// harness. Weights are seeded (`Weights::random`, no checkpoint) and
/// every exported figure lives on the modelled clock, so for one
/// (scenario, seed) the arrival schedule, per-request tokens and all
/// exports are byte-identical across runs and hosts. Exits non-zero on
/// any lost, failed or shed request — the CI cluster-smoke job asserts
/// zero loss across an injected replica failure this way.
fn cmd_loadtest(args: &Args) -> truedepth::Result<()> {
    use truedepth::cluster::{loadgen, Cluster, FaultPlan, LoadTrace, Scenario};
    let model = args.get_or("model", "td-small").to_string();
    let replicas = args.get_usize("replicas", 2);
    let seed = args.get_usize("seed", 42) as u64;
    let n_requests = args.get_usize("requests", 48);
    let scenario_name = args.get_or("scenario", "mixed");
    let scenario = Scenario::parse(scenario_name).ok_or_else(|| {
        truedepth::Error::msg(format!(
            "unknown scenario `{scenario_name}` (steady|bursty|multiturn|flood|mixed)"
        ))
    })?;
    // deep enough that back-pressure never sheds by default, so zero-loss
    // is assertable; shrink it deliberately to study shedding
    let queue_depth = args.get_usize("queue-depth", n_requests.max(8));
    let paged = args.flag("paged");
    let pool = args.get_usize("page-pool", 0);
    let run_cfg = match args.get("config") {
        Some(p) => truedepth::config::RunConfig::from_file(std::path::Path::new(p))?,
        None => truedepth::config::RunConfig::default(),
    };
    let net = cost_net(args, &run_cfg);
    let device = run_cfg.device.clone();
    let manifest = truedepth::runtime::Manifest::load_default()?;
    let cfg = manifest.model(&model)?.config.clone();
    let model_name = model.clone();
    let factory: truedepth::cluster::ModelFactory = Box::new(move |_i| {
        // same seed per replica: replicas are bit-identical, so a migrated
        // request replays to the same tokens it would have produced
        let weights = truedepth::model::Weights::random(&cfg, 11);
        let cost = truedepth::parallel::CostModel::new(net.clone(), device.clone());
        let mut serving =
            ServingModel::from_manifest_with_cost(&manifest, &model_name, &weights, cost)?;
        if paged {
            serving.enable_paging()?;
            if pool > 0 {
                serving.set_page_capacity(pool);
            }
        }
        Ok(serving)
    });
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    let tracers = trace_out
        .as_ref()
        .map(|_| (0..replicas).map(|_| std::sync::Arc::new(Tracer::new())).collect::<Vec<_>>());
    let mut cluster =
        Cluster::with_tracers(&model, factory, replicas, queue_depth, tracers.clone())?;
    let tiers = cluster.models_response().models[0].tiers.clone();
    let trace = LoadTrace::generate(scenario, seed, n_requests, &tiers);
    if let Some(path) = args.get("arrivals-out") {
        std::fs::write(path, trace.to_json())?;
        println!("arrivals: {path} ({} arrivals)", trace.arrivals.len());
    }
    let fault = args.get("fail-replica").map(|_| FaultPlan {
        replica: args.get_usize("fail-replica", 0),
        fail_at_step: args.get_usize("fail-at-step", 5) as u64,
        respawn_at_step: args
            .get("respawn-at-step")
            .map(|_| args.get_usize("respawn-at-step", 0) as u64),
    });
    if let Some(f) = &fault {
        match f.respawn_at_step {
            Some(s) => println!(
                "fault plan: fail replica {} at step {}, respawn at step {s}",
                f.replica, f.fail_at_step
            ),
            None => println!("fault plan: fail replica {} at step {}", f.replica, f.fail_at_step),
        }
    }
    let report = loadgen::run(&mut cluster, &trace, fault.as_ref())?;
    println!(
        "loadtest {scenario_name} seed={seed}: {} arrivals, {} completed, {} failed, {} shed, \
         {} steps",
        trace.arrivals.len(),
        report.completed(),
        report.failed(),
        report.rejected(),
        report.steps
    );
    println!("{}", cluster.metrics.report());
    if let (Some(trs), Some(path)) = (&tracers, &trace_out) {
        for (i, tr) in trs.iter().enumerate() {
            let p = replica_trace_path(path, i);
            tr.write_chrome(&p)?;
            println!("trace: {} ({} events)", p.display(), tr.len());
        }
    }
    if let Some(path) = &metrics_out {
        cluster.snapshot("loadtest").write(path)?;
        println!("metrics snapshot: {}", path.display());
    }
    if report.failed() > 0 || report.rejected() > 0 {
        return Err(truedepth::Error::msg(format!(
            "loadtest lost work: {} failed, {} shed",
            report.failed(),
            report.rejected()
        )));
    }
    Ok(())
}
