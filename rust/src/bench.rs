//! Micro-bench harness (substrate: no criterion in the offline vendor set).
//!
//! Usage inside a `harness = false` bench target:
//! ```no_run
//! let mut b = truedepth::bench::Bench::new("bench_hostops");
//! b.bench("add_64k", || { /* work */ });
//! b.finish();
//! ```
//! Prints criterion-style `name  time/iter ± σ  (n iters)` lines and writes
//! a machine-readable JSON report next to the target dir. Besides timing
//! samples, a report can carry named **deterministic metrics**
//! ([`Bench::metric`]) — modelled tokens/sec, flops/token, α–β payloads —
//! which is what the CI perf-regression gate (`bin/perf_gate.rs`) compares
//! against the checked-in `rust/bench-baseline.json`.

use std::time::{Duration, Instant};

use crate::util::json::{arr, num, obj, s, Value};
use crate::util::stats::{fmt_duration, Summary};

pub struct Bench {
    group: String,
    results: Vec<(String, Summary)>,
    /// Named deterministic metrics for the JSON report (`"metrics"` key).
    metrics: Vec<(String, f64)>,
    /// Minimum measurement time per benchmark.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    /// Max iterations (cap for very slow benchmarks).
    pub max_iters: u64,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        println!("== {group} ==");
        Bench {
            group: group.to_string(),
            results: vec![],
            metrics: vec![],
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(150),
            max_iters: 1_000_000,
        }
    }

    /// Record a named deterministic metric (modelled time, flop counts,
    /// payload bytes, …) into the JSON report's `"metrics"` object. Unlike
    /// the timing samples these are machine-independent, so CI can fail a
    /// PR on a small relative change (`bin/perf_gate.rs`).
    pub fn metric(&mut self, name: &str, value: f64) {
        println!("   metric {name} = {value:.4}");
        self.metrics.push((name.to_string(), value));
    }

    /// Benchmark `f`, auto-picking the iteration count.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        // warmup + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < self.warmup_time || calib_iters == 0 {
            f();
            calib_iters += 1;
            if calib_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        // choose sample layout: ~20 samples over measure_time
        let samples = 20usize;
        let iters_per_sample =
            ((self.measure_time.as_secs_f64() / samples as f64 / per_iter).ceil() as u64)
                .clamp(1, self.max_iters);
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            times.push(t.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        let summary = Summary::from(&times);
        println!(
            "{name:<40} {:>12}/iter ± {:<10} ({} × {} iters)",
            fmt_duration(summary.mean),
            fmt_duration(summary.std),
            samples,
            iters_per_sample
        );
        self.results.push((name.to_string(), summary));
    }

    /// Benchmark with a measured-section closure returning its own duration
    /// (for workloads needing per-iter setup that must not be timed).
    ///
    /// The first invocation is a discarded warmup: it pays one-time costs
    /// (lazy executable compilation, cache fill) that would otherwise skew
    /// the reported stats — and with them any baseline comparison — by
    /// folding first-compile cost into the sample mean.
    pub fn bench_timed(&mut self, name: &str, samples: usize, mut f: impl FnMut() -> Duration) {
        let _cold = f(); // warmup, excluded from the stats
        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            times.push(f().as_nanos() as f64);
        }
        let summary = Summary::from(&times);
        println!(
            "{name:<40} {:>12}/iter ± {:<10} ({samples} samples)",
            fmt_duration(summary.mean),
            fmt_duration(summary.std),
        );
        self.results.push((name.to_string(), summary));
    }

    /// Write the JSON report and return the result count.
    pub fn finish(self) -> usize {
        let entries: Vec<Value> = self
            .results
            .iter()
            .map(|(name, sm)| {
                obj(vec![
                    ("name", s(name.clone())),
                    ("mean_ns", num(sm.mean)),
                    ("std_ns", num(sm.std)),
                    ("p50_ns", num(sm.p50)),
                    ("p99_ns", num(sm.p99)),
                ])
            })
            .collect();
        let metrics = obj(
            self.metrics
                .iter()
                .map(|(name, v)| (name.as_str(), num(*v)))
                .collect(),
        );
        let report = obj(vec![
            ("group", s(self.group.clone())),
            ("results", arr(entries)),
            ("metrics", metrics),
        ]);
        let dir = crate::repo_root().join("target/bench-reports");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join(format!("{}.json", self.group));
        let _ = std::fs::write(&path, report.to_string_pretty());
        println!("(report: {})", path.display());
        self.results.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut b = Bench::new("selftest");
        b.measure_time = Duration::from_millis(20);
        b.warmup_time = Duration::from_millis(5);
        let mut x = 0u64;
        b.bench("noop", || {
            x = x.wrapping_add(1);
        });
        assert_eq!(b.finish(), 1);
        assert!(x > 0);
    }

    #[test]
    fn bench_timed_uses_given_durations() {
        let mut b = Bench::new("selftest2");
        b.bench_timed("fixed", 5, || Duration::from_micros(100));
        assert_eq!(b.finish(), 1);
    }

    /// Satellite bugfix regression: the cold first iteration (lazy compile,
    /// cache fill) must be excluded from the reported stats.
    #[test]
    fn bench_timed_discards_cold_first_iteration() {
        let mut b = Bench::new("selftest3");
        let mut calls = 0u32;
        b.bench_timed("warm", 4, || {
            calls += 1;
            if calls == 1 {
                Duration::from_secs(10) // pathological first-compile cost
            } else {
                Duration::from_micros(50)
            }
        });
        assert_eq!(calls, 5, "warmup + 4 samples");
        let (_, summary) = &b.results[0];
        assert_eq!(summary.n, 4);
        assert!(
            (summary.mean - 50_000.0).abs() < 1e-6,
            "cold iteration leaked into the stats: mean {} ns",
            summary.mean
        );
        assert_eq!(b.finish(), 1);
    }

    #[test]
    fn metrics_land_in_the_json_report() {
        let mut b = Bench::new("selftest4");
        b.metric("modelled_tok_per_s", 123.5);
        b.metric("payload_bytes", 4096.0);
        b.finish();
        let path = crate::repo_root().join("target/bench-reports/selftest4.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::Value::parse(&text).unwrap();
        let m = v.get("metrics").expect("metrics object");
        assert_eq!(m.get("modelled_tok_per_s").unwrap().as_f64(), Some(123.5));
        assert_eq!(m.get("payload_bytes").unwrap().as_f64(), Some(4096.0));
    }
}
