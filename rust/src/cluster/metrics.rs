//! Cluster-wide metrics: routing decisions, fail-over accounting, and the
//! modelled cluster-level latency distributions.
//!
//! Same determinism contract as `coordinator::ServerMetrics`: only
//! simulated-clock figures and pure counters are exported, so two
//! identical seeded runs serialize byte-identically and the cluster p50/
//! p99 TTFT/latency can be gated in `rust/bench-baseline.json`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::Response;
use crate::util::json::{self, Value};
use crate::util::stats::{Reservoir, Summary};

/// Reservoir capacity (matches `ServerMetrics`): percentiles come from a
/// deterministic bounded sample while n/min/max stay exact.
const RESERVOIR_CAP: usize = 1024;

pub struct ClusterMetrics {
    /// Requests accepted by the cluster front door (routed to a replica).
    pub submitted: AtomicU64,
    /// Requests that finished successfully (terminal `Done` without error).
    pub completed: AtomicU64,
    /// Requests that finished with a typed error (rejection or fault).
    pub failed: AtomicU64,
    /// Routing decisions resolved by session affinity (the request's
    /// session key was already pinned to a healthy replica).
    pub affinity_hits: AtomicU64,
    /// Requests re-routed to a sibling because their replica was fenced.
    pub migrations: AtomicU64,
    /// Replica fence events (`Cluster::fail_replica`).
    pub failovers: AtomicU64,
    /// Replica respawn events (`Cluster::respawn_replica`).
    pub respawns: AtomicU64,
    /// Per-replica routed-request counts (index = replica).
    routed: Mutex<Vec<u64>>,
    /// Modelled (simulated-clock) cluster-level latency distributions,
    /// fed from each completion's internal modelled fields.
    modelled_ttft_ms: Mutex<Reservoir>,
    modelled_latency_ms: Mutex<Reservoir>,
}

impl ClusterMetrics {
    pub fn new(replicas: usize) -> ClusterMetrics {
        // fixed distinct seeds, like ServerMetrics: reproducible sampling
        ClusterMetrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            affinity_hits: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            routed: Mutex::new(vec![0; replicas]),
            modelled_ttft_ms: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0xc1a5_7f71)),
            modelled_latency_ms: Mutex::new(Reservoir::new(RESERVOIR_CAP, 0xc1a5_1a7e)),
        }
    }

    /// Record a routing decision landing on `replica`.
    pub fn record_routed(&self, replica: usize) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let mut r = self.routed.lock().unwrap();
        if r.len() <= replica {
            r.resize(replica + 1, 0);
        }
        r[replica] += 1;
    }

    /// Record a terminal event as it passes through the cluster pump.
    pub fn record_done(&self, resp: &Response) {
        if resp.error.is_some() {
            self.failed.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.modelled_ttft_ms.lock().unwrap().push(resp.modelled_ttft_ms);
        self.modelled_latency_ms.lock().unwrap().push(resp.modelled_latency_ms);
    }

    /// Per-replica routed counts.
    pub fn routed_per_replica(&self) -> Vec<u64> {
        self.routed.lock().unwrap().clone()
    }

    /// Modelled cluster-level TTFT distribution (deterministic).
    pub fn modelled_ttft_summary(&self) -> Option<Summary> {
        self.modelled_ttft_ms.lock().unwrap().summary()
    }

    /// Modelled cluster-level end-to-end latency distribution.
    pub fn modelled_latency_summary(&self) -> Option<Summary> {
        self.modelled_latency_ms.lock().unwrap().summary()
    }

    /// The `cluster` snapshot section (deterministic figures only); nests
    /// under `obs::MetricsSnapshot::with_section`, flattening to perf-gate
    /// keys like `<source>.cluster.modelled_latency_ms.p99`.
    pub fn to_json(&self) -> Value {
        let n = |a: &AtomicU64| json::num(a.load(Ordering::Relaxed) as f64);
        let mut sec: Vec<(&str, Value)> = vec![
            ("submitted", n(&self.submitted)),
            ("completed", n(&self.completed)),
            ("failed", n(&self.failed)),
            ("affinity_hits", n(&self.affinity_hits)),
            ("migrations", n(&self.migrations)),
            ("failovers", n(&self.failovers)),
            ("respawns", n(&self.respawns)),
            (
                "routed_per_replica",
                json::arr(
                    self.routed_per_replica().iter().map(|&c| json::num(c as f64)).collect(),
                ),
            ),
        ];
        if let Some(s) = self.modelled_ttft_summary() {
            sec.push(("modelled_ttft_ms", summary_json(&s)));
        }
        if let Some(s) = self.modelled_latency_summary() {
            sec.push(("modelled_latency_ms", summary_json(&s)));
        }
        json::obj(sec)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "cluster: {} routed ({} affinity), {} completed, {} failed; {} migrations, {} failovers, {} respawns",
            self.submitted.load(Ordering::Relaxed),
            self.affinity_hits.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.migrations.load(Ordering::Relaxed),
            self.failovers.load(Ordering::Relaxed),
            self.respawns.load(Ordering::Relaxed),
        );
        let routed = self.routed_per_replica();
        let cells: Vec<String> =
            routed.iter().enumerate().map(|(i, c)| format!("r{i}×{c}")).collect();
        s += &format!("\nrouted per replica: {}", cells.join(" "));
        if let Some(t) = self.modelled_ttft_summary() {
            s += &format!(
                "\nmodelled cluster ttft ms: p50 {:.2} p90 {:.2} p99 {:.2}",
                t.p50, t.p90, t.p99
            );
        }
        if let Some(l) = self.modelled_latency_summary() {
            s += &format!(
                "\nmodelled cluster latency ms: p50 {:.2} p90 {:.2} p99 {:.2}",
                l.p50, l.p90, l.p99
            );
        }
        s
    }
}

fn summary_json(s: &Summary) -> Value {
    json::obj(vec![
        ("n", json::num(s.n as f64)),
        ("mean", json::num(s.mean)),
        ("std", json::num(s.std)),
        ("min", json::num(s.min)),
        ("p50", json::num(s.p50)),
        ("p90", json::num(s.p90)),
        ("p99", json::num(s.p99)),
        ("max", json::num(s.max)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ApiError, ErrorCode};

    fn done(modelled_ttft: f64, modelled_latency: f64) -> Response {
        Response {
            id: 1,
            tier: Some("lp".into()),
            text: "x".into(),
            tokens: vec![1, 2],
            prompt_tokens: 3,
            ttft_ms: 5.0,
            latency_ms: 9.0,
            modelled_ttft_ms: modelled_ttft,
            modelled_latency_ms: modelled_latency,
            error: None,
        }
    }

    #[test]
    fn counters_routing_and_summaries() {
        let m = ClusterMetrics::new(2);
        m.record_routed(0);
        m.record_routed(1);
        m.record_routed(1);
        assert_eq!(m.routed_per_replica(), vec![1, 2]);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 3);
        m.record_done(&done(4.0, 40.0));
        m.record_done(&done(6.0, 60.0));
        m.record_done(&Response::failed(9, ApiError::new(ErrorCode::Overloaded, "full")));
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        let t = m.modelled_ttft_summary().unwrap();
        assert!((t.p50 - 5.0).abs() < 1e-9, "failures must not pollute the reservoirs");
        let r = m.report();
        assert!(r.contains("3 routed") && r.contains("r1×2"), "{r}");
        assert!(r.contains("modelled cluster latency"), "{r}");
    }

    /// The exported section only carries deterministic figures and
    /// serializes identically for identical states.
    #[test]
    fn section_is_deterministic_and_flattens() {
        let build = || {
            let m = ClusterMetrics::new(2);
            m.record_routed(0);
            m.record_done(&done(4.0, 40.0));
            m
        };
        let a = build().to_json().to_string_pretty();
        let b = build().to_json().to_string_pretty();
        assert_eq!(a, b);
        let snap = crate::obs::MetricsSnapshot::new("loadtest")
            .with_section("cluster", build().to_json());
        let doc = crate::util::json::Value::parse(&snap.to_string_pretty()).unwrap();
        let flat = crate::obs::MetricsSnapshot::flatten(&doc);
        assert_eq!(flat.get("loadtest.cluster.completed"), Some(&1.0));
        assert_eq!(flat.get("loadtest.cluster.modelled_latency_ms.p50"), Some(&40.0));
    }
}
