//! Cost-model replica router.
//!
//! The deterministic SimNet runtime gives the cluster a load signal real
//! deployments have to estimate: every replica's mesh carries a modelled
//! clock (`MeshMetrics::modelled_total_ns`) that advances only with the
//! work actually executed, and `ServerMetrics::tier_stats` prices a token
//! on each tier from rounds that already ran. Routing therefore picks the
//! replica with the *earliest modelled finish time* for the new request:
//!
//! ```text
//! finish(r) = clock_ns(r) + (backlog(r) + 1) · expected_tokens · cost_ns(r)
//! ```
//!
//! where `backlog` counts queued + admitted-but-unfinished requests and
//! `cost_ns` is the modelled ns/token for the request's tier on that
//! replica (falling back to the replica's overall modelled decode rate).
//!
//! Until a replica has decoded anything its cost is unknown; when *no*
//! healthy replica has a cost signal yet, the router degrades to the
//! least-backlog policy (the policy of the old `coordinator::router`
//! stub, absorbed here). All ties break toward the lowest replica index,
//! keeping the decision deterministic.

/// One replica's routing inputs, sampled at decision time. `None` in the
/// cluster's signal vector marks a fenced (failed, not yet respawned)
/// replica, which is never eligible.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteSignal {
    /// Queued + admitted-but-unfinished requests on the replica.
    pub backlog: usize,
    /// The replica mesh's modelled clock, ns.
    pub clock_ns: u64,
    /// Modelled ns per generated token for the request's tier on this
    /// replica; `None` until the replica has decode history.
    pub cost_per_token_ns: Option<f64>,
}

/// Pick the replica with the earliest modelled finish for a request
/// expected to generate `expected_tokens` tokens. Returns `None` only
/// when every replica is fenced.
pub fn pick(signals: &[Option<RouteSignal>], expected_tokens: usize) -> Option<usize> {
    let any_cost = signals
        .iter()
        .flatten()
        .any(|s| s.cost_per_token_ns.is_some());
    let mut best: Option<(usize, f64)> = None;
    for (i, sig) in signals.iter().enumerate() {
        let Some(sig) = sig else { continue };
        let score = if any_cost {
            // replicas with no history yet price at cost 0: they are idle
            // or near-idle and should win until they have a real signal
            let cost = sig.cost_per_token_ns.unwrap_or(0.0);
            sig.clock_ns as f64 + (sig.backlog as f64 + 1.0) * expected_tokens as f64 * cost
        } else {
            // least-loaded fallback (migrated from the deleted router stub)
            sig.backlog as f64
        };
        // strict `<` keeps ties on the lowest index
        match best {
            Some((_, b)) if score >= b => {}
            _ => best = Some((i, score)),
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(backlog: usize, clock_ns: u64, cost: Option<f64>) -> Option<RouteSignal> {
        Some(RouteSignal { backlog, clock_ns, cost_per_token_ns: cost })
    }

    #[test]
    fn no_healthy_replica_routes_nowhere() {
        assert_eq!(pick(&[], 8), None);
        assert_eq!(pick(&[None, None], 8), None);
    }

    #[test]
    fn fallback_is_least_backlog_with_low_index_ties() {
        // no replica has decode history → least-backlog policy
        let s = [sig(3, 900, None), sig(1, 0, None), sig(1, 0, None)];
        assert_eq!(pick(&s, 8), Some(1));
    }

    #[test]
    fn cost_model_prefers_earliest_modelled_finish() {
        // replica 0: ahead on the clock but fast and idle;
        // replica 1: behind on the clock but slow and backlogged.
        // finish(0) = 10_000 + 1·16·100  = 11_600
        // finish(1) =  2_000 + 3·16·500  = 26_000
        let s = [sig(0, 10_000, Some(100.0)), sig(2, 2_000, Some(500.0))];
        assert_eq!(pick(&s, 16), Some(0));
        // longer requests amortize the clock head start the same way
        assert_eq!(pick(&s, 1_000), Some(0));
    }

    #[test]
    fn cold_replica_wins_until_it_has_history() {
        // one replica has a cost signal, the other is fresh (respawned):
        // the fresh one prices at 0 and absorbs load until it warms up
        let s = [sig(4, 50_000, Some(200.0)), sig(0, 0, None)];
        assert_eq!(pick(&s, 8), Some(1));
    }

    #[test]
    fn fenced_replicas_are_skipped() {
        let s = [None, sig(9, 5_000, Some(10.0)), None];
        assert_eq!(pick(&s, 8), Some(1));
    }
}
