//! L4 cluster: multi-replica serving over R independent simulated meshes.
//!
//! A [`Cluster`] owns R replicas — each a full [`Scheduler`]/[`Batcher`]
//! pair over its own [`ServingModel`] mesh — behind one typed front door,
//! [`Cluster::submit`]. The whole cluster is driven in *lockstep*:
//! [`Cluster::step`] runs one scheduler tick per healthy replica in
//! replica-index order, then pumps reply streams in request-id order, so
//! a seeded workload produces bit-identical results, metrics and traces
//! on every run (no scheduler threads, no wall-clock in any exported
//! figure).
//!
//! ## Routing signal
//!
//! Each replica's SimNet mesh carries a modelled clock and per-tier
//! decode rates — a free, deterministic load signal. Routing picks the
//! replica with the earliest modelled finish time for the new request
//! (see [`router`]); before any decode history exists it degrades to
//! least-backlog. Decisions are deterministic: ties break to the lowest
//! replica index.
//!
//! ## Session affinity
//!
//! A request carrying a `session` key is pinned to the replica that
//! served the session's previous turns, so the paged-KV shared-prefix
//! index ([`crate::model::kvcache`]) keeps multi-turn prefix reuse local
//! — `kv.prefix_hits` accrue on the affine replica instead of being
//! scattered. Pins move only when the pinned replica is fenced.
//!
//! ## Drain/respawn state machine
//!
//! ```text
//!           fail_replica(i)                respawn_replica(i)
//! HEALTHY ───────────────────▶ FENCED ───────────────────────▶ HEALTHY
//!  sched: Some                 sched: None                     fresh Scheduler,
//!                                                              same ServerMetrics
//!    fence   take the Scheduler (no new admissions possible)
//!    drain   eject admitted work (Scheduler::eject_all) + queued
//!            batcher backlog; displaced jobs re-route to healthy
//!            siblings through the cost router (counted as
//!            migrations), keeping their original reply streams
//!    replay  a migrated request re-runs from scratch on the
//!            sibling; decode is deterministic per request, so the
//!            re-run reproduces the already-streamed tokens and the
//!            pump dedups them by index — callers see each token
//!            exactly once and exactly one terminal Done
//! ```
//!
//! If no healthy sibling remains, displaced requests fail with a typed
//! error — never silently lost: every submitted request gets exactly one
//! terminal event.

pub mod loadgen;
pub mod metrics;
pub mod router;

pub use loadgen::{FaultPlan, LoadReport, LoadTrace, Scenario};
pub use metrics::ClusterMetrics;
pub use router::RouteSignal;

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::{ApiError, CompletionRequest, ErrorCode, ModelInfo, ModelsResponse};
use crate::coordinator::batcher::{Batcher, SubmitError};
use crate::coordinator::request::{Job, Request, Response, TokenEvent};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::{ResponseHandle, ServerMetrics};
use crate::error::{Error, Result};
use crate::model::ServingModel;
use crate::obs::{MetricsSnapshot, Tracer};

/// Builds the serving model for replica `i` — used at construction and
/// again on [`Cluster::respawn_replica`]. Replicas are symmetric; the
/// index is provided for logging/asymmetric-test scenarios.
pub type ModelFactory = Box<dyn Fn(usize) -> Result<ServingModel> + Send>;

struct Replica {
    batcher: Arc<Batcher>,
    /// `None` = fenced (failed, awaiting respawn).
    sched: Option<Scheduler>,
    /// Survives fence/respawn cycles: one metrics lineage per replica slot.
    metrics: Arc<ServerMetrics>,
    tracer: Option<Arc<Tracer>>,
}

/// Per-request interposition between a replica's reply stream and the
/// caller's [`ResponseHandle`]. Forwards tokens in index order exactly
/// once (dropping the duplicate prefix a migrated request re-streams)
/// and exactly one terminal `Done`.
struct Pump {
    rx: Receiver<TokenEvent>,
    tx: Sender<TokenEvent>,
    next_index: usize,
    replica: usize,
    session: Option<String>,
}

pub struct Cluster {
    model_name: String,
    tiers: Vec<String>,
    default_tier: String,
    replicas: Vec<Replica>,
    factory: ModelFactory,
    /// Request-id order — pumping iterates this map, so delivery order
    /// across requests is deterministic.
    pumps: BTreeMap<u64, Pump>,
    /// session key → pinned replica.
    sessions: BTreeMap<String, usize>,
    pub metrics: Arc<ClusterMetrics>,
    next_id: u64,
}

impl Cluster {
    /// Build a cluster of `replicas` symmetric replicas; `factory(i)` is
    /// called once per replica (and again on respawn). `queue_depth`
    /// bounds each replica's admission queue.
    pub fn new(
        model_name: &str,
        factory: ModelFactory,
        replicas: usize,
        queue_depth: usize,
    ) -> Result<Cluster> {
        Cluster::with_tracers(model_name, factory, replicas, queue_depth, None)
    }

    /// Like [`Cluster::new`] with one span recorder per replica (index-
    /// aligned); each replica's scheduler + mesh events land in its own
    /// tracer, plus cluster routing/migration instants.
    pub fn with_tracers(
        model_name: &str,
        factory: ModelFactory,
        replicas: usize,
        queue_depth: usize,
        tracers: Option<Vec<Arc<Tracer>>>,
    ) -> Result<Cluster> {
        if replicas == 0 {
            return Err(Error::Serving("cluster needs at least one replica".into()));
        }
        if let Some(t) = &tracers {
            if t.len() != replicas {
                return Err(Error::Serving(format!(
                    "got {} tracers for {replicas} replicas",
                    t.len()
                )));
            }
        }
        let mut reps = Vec::with_capacity(replicas);
        let mut tiers = Vec::new();
        let mut default_tier = String::new();
        for i in 0..replicas {
            let model = factory(i)?;
            if i == 0 {
                tiers = model.variant_ids().iter().map(|v| v.to_string()).collect();
                default_tier = model.default_tier().to_string();
            }
            let metrics = Arc::new(ServerMetrics::default());
            let tracer = tracers.as_ref().map(|t| t[i].clone());
            let sched = Scheduler::with_tracer(model, metrics.clone(), tracer.clone());
            reps.push(Replica {
                batcher: Arc::new(Batcher::new(queue_depth)),
                sched: Some(sched),
                metrics,
                tracer,
            });
        }
        Ok(Cluster {
            model_name: model_name.to_string(),
            tiers,
            default_tier,
            replicas: reps,
            factory,
            pumps: BTreeMap::new(),
            sessions: BTreeMap::new(),
            metrics: Arc::new(ClusterMetrics::new(replicas)),
            next_id: 1,
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn healthy_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.sched.is_some()).count()
    }

    pub fn is_healthy(&self, idx: usize) -> bool {
        self.replicas.get(idx).is_some_and(|r| r.sched.is_some())
    }

    /// Replica `idx`'s metrics lineage (stable across fence/respawn).
    pub fn replica_metrics(&self, idx: usize) -> Arc<ServerMetrics> {
        self.replicas[idx].metrics.clone()
    }

    /// The `GET /v1/models` payload for this deployment.
    pub fn models_response(&self) -> ModelsResponse {
        ModelsResponse {
            models: vec![ModelInfo {
                model: self.model_name.clone(),
                tiers: self.tiers.clone(),
                default_tier: self.default_tier.clone(),
            }],
            replicas: self.replicas.len(),
        }
    }

    /// Route and enqueue a request; the returned handle streams tokens
    /// and resolves to the final [`Response`] as [`Cluster::step`] is
    /// driven. Fails fast (no handle) only when no replica can accept:
    /// every accepted request is guaranteed a terminal event.
    pub fn submit(&mut self, req: CompletionRequest) -> Result<ResponseHandle> {
        let session = req.session.clone();
        let Some(replica) =
            self.route(req.tier.as_deref(), req.max_tokens, session.as_deref())
        else {
            return Err(Error::Serving("no healthy replicas".into()));
        };
        let id = self.next_id;
        self.next_id += 1;
        let (sched_tx, sched_rx) = channel();
        let (caller_tx, caller_rx) = channel();
        let opts = req.options();
        let job = Job {
            request: Request { id, prompt: req.prompt, opts, submitted_at: Instant::now() },
            reply: sched_tx,
        };
        let rep = &self.replicas[replica];
        rep.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        match rep.batcher.submit(job) {
            Ok(()) => {}
            Err(SubmitError::Full(_)) => {
                rep.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Overloaded("queue full (back-pressure)".into()));
            }
            Err(SubmitError::Closed(_)) => {
                return Err(Error::Serving("replica shutting down".into()))
            }
        }
        self.trace_instant(replica, "routed", &[("request", id.to_string())]);
        if let Some(key) = &session {
            self.sessions.insert(key.clone(), replica);
        }
        self.metrics.record_routed(replica);
        self.pumps.insert(
            id,
            Pump { rx: sched_rx, tx: caller_tx, next_index: 0, replica, session },
        );
        Ok(ResponseHandle::new(id, caller_rx))
    }

    /// One lockstep iteration: a scheduler tick per healthy replica (in
    /// index order), then pump reply streams. Returns `false` once the
    /// cluster is fully drained.
    pub fn step(&mut self) -> bool {
        for i in 0..self.replicas.len() {
            let batcher = self.replicas[i].batcher.clone();
            if let Some(sched) = self.replicas[i].sched.as_mut() {
                sched.step(&batcher);
            }
        }
        self.pump();
        !self.is_idle()
    }

    /// No queued, admitted, or un-pumped work anywhere.
    pub fn is_idle(&self) -> bool {
        self.pumps.is_empty()
            && self.replicas.iter().all(|r| {
                r.batcher.is_empty() && r.sched.as_ref().is_none_or(|s| s.is_idle())
            })
    }

    /// Drive [`Cluster::step`] until idle; errors if the cluster fails to
    /// drain within `max_steps` (a stuck-work guard for tests/CLIs).
    pub fn run_to_idle(&mut self, max_steps: usize) -> Result<usize> {
        for step in 0..max_steps {
            if !self.step() {
                return Ok(step);
            }
        }
        Err(Error::Serving(format!("cluster failed to drain within {max_steps} steps")))
    }

    /// Flush per-replica mesh event tracks into their tracers (call once
    /// after the run, before exporting traces).
    pub fn finish(&self) {
        for r in &self.replicas {
            if let Some(s) = &r.sched {
                s.flush_mesh_trace();
            }
        }
    }

    /// Fence replica `idx` and migrate its work: no new admissions, all
    /// queued + in-flight requests drain to healthy siblings (or fail
    /// typed if none remain). Returns the number of displaced requests.
    /// Idempotent on an already-fenced replica.
    pub fn fail_replica(&mut self, idx: usize) -> usize {
        let Some(mut sched) = self.replicas[idx].sched.take() else { return 0 };
        self.metrics.failovers.fetch_add(1, Ordering::Relaxed);
        let mut displaced = sched.eject_all();
        displaced.extend(self.replicas[idx].batcher.drain(usize::MAX, Duration::ZERO));
        displaced.sort_by_key(|j| j.request.id);
        sched.flush_mesh_trace();
        drop(sched);
        let n = displaced.len();
        for job in displaced {
            self.reroute(job);
        }
        n
    }

    /// Rebuild a fenced replica's model (same factory, same metrics
    /// lineage) and return it to the routable pool. No-op if healthy.
    pub fn respawn_replica(&mut self, idx: usize) -> Result<()> {
        if self.replicas[idx].sched.is_some() {
            return Ok(());
        }
        let model = (self.factory)(idx)?;
        let rep = &mut self.replicas[idx];
        rep.sched = Some(Scheduler::with_tracer(model, rep.metrics.clone(), rep.tracer.clone()));
        self.metrics.respawns.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Cluster-wide deterministic metrics document: the `cluster` section
    /// plus one `replicaN` server section per replica.
    pub fn snapshot(&self, source: &str) -> MetricsSnapshot {
        let mut snap =
            MetricsSnapshot::new(source).with_section("cluster", self.metrics.to_json());
        for (i, r) in self.replicas.iter().enumerate() {
            snap = snap.with_server_named(&format!("replica{i}"), &r.metrics);
        }
        snap
    }

    // ---- internals ---------------------------------------------------------

    fn route(
        &self,
        tier: Option<&str>,
        expected_tokens: usize,
        session: Option<&str>,
    ) -> Option<usize> {
        if let Some(key) = session {
            if let Some(&r) = self.sessions.get(key) {
                if self.replicas[r].sched.is_some() {
                    self.metrics.affinity_hits.fetch_add(1, Ordering::Relaxed);
                    return Some(r);
                }
            }
        }
        let signals: Vec<Option<RouteSignal>> = self
            .replicas
            .iter()
            .map(|r| {
                let sched = r.sched.as_ref()?;
                Some(RouteSignal {
                    backlog: r.batcher.len() + sched.admitted_len(),
                    clock_ns: sched.model().mesh.metrics.modelled_total_ns(),
                    cost_per_token_ns: tier_cost_ns(&r.metrics, sched.model(), tier),
                })
            })
            .collect();
        router::pick(&signals, expected_tokens)
    }

    /// Re-route one displaced job after a fence, keeping its original
    /// reply stream (the caller's pump keeps working untouched).
    fn reroute(&mut self, job: Job) {
        let id = job.request.id;
        let tier = job.request.opts.tier.clone();
        let expected = job.request.opts.max_new_tokens;
        let session = self.pumps.get(&id).and_then(|p| p.session.clone());
        let Some(target) = self.route(tier.as_deref(), expected, session.as_deref()) else {
            let _ = job.reply.send(TokenEvent::Done(Response::failed(
                id,
                ApiError::new(ErrorCode::Internal, "replica failed; no healthy sibling"),
            )));
            return;
        };
        let rep = &self.replicas[target];
        rep.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        match rep.batcher.submit(job) {
            Ok(()) => {
                self.metrics.migrations.fetch_add(1, Ordering::Relaxed);
                self.trace_instant(target, "migrated", &[("request", id.to_string())]);
                if let Some(p) = self.pumps.get_mut(&id) {
                    p.replica = target;
                }
                if let Some(key) = session {
                    self.sessions.insert(key, target);
                }
            }
            Err(SubmitError::Full(job)) | Err(SubmitError::Closed(job)) => {
                rep.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(TokenEvent::Done(Response::failed(
                    id,
                    ApiError::new(ErrorCode::Overloaded, "replica failed; sibling queue full"),
                )));
            }
        }
    }

    /// Forward buffered reply events to callers, in request-id order.
    /// Tokens are deduped by index (migration replay) and each request
    /// sees exactly one terminal `Done`.
    fn pump(&mut self) {
        let cm = self.metrics.clone();
        let mut finished = Vec::new();
        for (&id, pump) in self.pumps.iter_mut() {
            loop {
                match pump.rx.try_recv() {
                    Ok(TokenEvent::Token { index, token, text }) => {
                        if index == pump.next_index {
                            pump.next_index += 1;
                            let _ = pump.tx.send(TokenEvent::Token { index, token, text });
                        }
                        // index < next_index: deterministic replay of a
                        // migrated request re-streaming its prefix — drop
                    }
                    Ok(TokenEvent::Done(resp)) => {
                        cm.record_done(&resp);
                        let _ = pump.tx.send(TokenEvent::Done(resp));
                        finished.push(id);
                        break;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // reply sender dropped without Done (should not
                        // happen in the fence path, which always re-routes
                        // or fails typed) — surface instead of hanging
                        let resp = Response::failed(
                            id,
                            ApiError::new(ErrorCode::Internal, "reply stream dropped"),
                        );
                        cm.record_done(&resp);
                        let _ = pump.tx.send(TokenEvent::Done(resp));
                        finished.push(id);
                        break;
                    }
                }
            }
        }
        for id in finished {
            self.pumps.remove(&id);
        }
    }

    fn trace_instant(&self, replica: usize, name: &str, args: &[(&str, String)]) {
        let rep = &self.replicas[replica];
        if let (Some(tr), Some(sched)) = (&rep.tracer, &rep.sched) {
            tr.instant(
                crate::obs::Track::Scheduler,
                name,
                sched.model().mesh.metrics.modelled_total_ns(),
                args,
            );
        }
    }
}

/// Modelled ns/token for `tier` on a replica — the request's tier's
/// observed rate when it has history, else the replica's overall decode
/// rate, else `None` (no signal yet).
fn tier_cost_ns(
    metrics: &ServerMetrics,
    model: &ServingModel,
    tier: Option<&str>,
) -> Option<f64> {
    if let Ok(vid) = model.resolve_tier(tier) {
        for (name, st) in metrics.tier_stats() {
            if name == vid.as_str() {
                if let Some(tps) = st.modelled_tok_per_s() {
                    return Some(1e9 / tps);
                }
            }
        }
    }
    metrics.modelled_decode_tok_per_s().map(|tps| 1e9 / tps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::InterconnectConfig;
    use crate::model::{transform, Weights};
    use crate::runtime::Manifest;

    /// Same graceful no-artifact gating as the server tests: `None`
    /// (skip) where the AOT manifest is absent.
    fn factory() -> Option<ModelFactory> {
        let manifest = Manifest::load_default().ok()?;
        let cfg = manifest.model("td-small").ok()?.config.clone();
        // probe once so construction failures skip instead of panic
        let weights = Weights::random(&cfg, 11);
        let plan = transform::pair_parallel(cfg.n_layers, 2, 10, true);
        ServingModel::new(
            &manifest,
            "td-small",
            &weights,
            &plan,
            InterconnectConfig { enabled: false, ..Default::default() },
        )
        .ok()?;
        Some(Box::new(move |_i| {
            let weights = Weights::random(&cfg, 11);
            let plan = transform::pair_parallel(cfg.n_layers, 2, 10, true);
            ServingModel::new(
                &manifest,
                "td-small",
                &weights,
                &plan,
                InterconnectConfig { enabled: false, ..Default::default() },
            )
        }))
    }

    fn drain(h: ResponseHandle) -> (Vec<i32>, Response) {
        let mut streamed = Vec::new();
        for ev in h.stream() {
            match ev {
                TokenEvent::Token { index, token, .. } => {
                    assert_eq!(index, streamed.len(), "token indices must be contiguous");
                    streamed.push(token);
                }
                TokenEvent::Done(r) => return (streamed, r),
            }
        }
        panic!("stream ended without Done");
    }

    #[test]
    fn two_replicas_serve_and_spread_load() {
        let Some(factory) = factory() else { return };
        let mut cluster = Cluster::new("td-small", factory, 2, 32).unwrap();
        let handles: Vec<_> = (0..6)
            .map(|i| {
                cluster
                    .submit(
                        CompletionRequest::new(format!("prompt {i} the red fox")).max_tokens(3),
                    )
                    .unwrap()
            })
            .collect();
        cluster.run_to_idle(10_000).unwrap();
        for h in handles {
            let (streamed, resp) = drain(h);
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(resp.generated_tokens(), 3);
            assert_eq!(streamed, resp.tokens);
            assert!(resp.modelled_latency_ms >= resp.modelled_ttft_ms);
        }
        let routed = cluster.metrics.routed_per_replica();
        assert_eq!(routed.iter().sum::<u64>(), 6);
        assert!(
            routed.iter().all(|&c| c > 0),
            "router must spread load across both replicas: {routed:?}"
        );
        assert_eq!(cluster.metrics.completed.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn session_affinity_pins_turns_to_one_replica() {
        let Some(factory) = factory() else { return };
        let mut cluster = Cluster::new("td-small", factory, 2, 32).unwrap();
        // interleave two sessions so plain load balancing would split them
        let mut handles = Vec::new();
        for turn in 0..3 {
            for sess in ["user-a", "user-b"] {
                let req = CompletionRequest::new(format!("{sess} turn {turn} the red fox"))
                    .max_tokens(2)
                    .session(sess);
                handles.push((sess, cluster.submit(req).unwrap()));
                cluster.run_to_idle(10_000).unwrap();
            }
        }
        let mut homes: BTreeMap<&str, u64> = BTreeMap::new();
        for (sess, h) in handles {
            let (_, resp) = drain(h);
            assert!(resp.error.is_none(), "{:?}", resp.error);
            *homes.entry(sess).or_default() += 1;
        }
        assert_eq!(homes["user-a"], 3);
        // turns 2..3 of each session hit the affinity map (turn 1 pins it)
        assert_eq!(cluster.metrics.affinity_hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn failing_all_replicas_yields_typed_errors_not_hangs() {
        let Some(factory) = factory() else { return };
        let mut cluster = Cluster::new("td-small", factory, 2, 32).unwrap();
        let h = cluster
            .submit(CompletionRequest::new("the red fox").max_tokens(4))
            .unwrap();
        cluster.step();
        cluster.fail_replica(0);
        cluster.fail_replica(1);
        assert_eq!(cluster.healthy_count(), 0);
        assert!(cluster.submit(CompletionRequest::new("x")).is_err(), "no replica can accept");
        cluster.run_to_idle(10_000).unwrap();
        let (_, resp) = drain(h);
        let err = resp.error.expect("displaced with no sibling must fail typed");
        assert_eq!(err.code, ErrorCode::Internal);
        // fenced → respawn restores service
        cluster.respawn_replica(0).unwrap();
        assert_eq!(cluster.healthy_count(), 1);
        let h = cluster.submit(CompletionRequest::new("the red fox").max_tokens(2)).unwrap();
        cluster.run_to_idle(10_000).unwrap();
        let (_, resp) = drain(h);
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(cluster.metrics.respawns.load(Ordering::Relaxed), 1);
    }
}
