//! Deterministic trace-driven load generation for the cluster.
//!
//! [`LoadTrace::generate`] expands a `(scenario, seed)` pair into a fixed
//! arrival schedule — bursty arrivals, heavy-tailed prompt lengths, mixed
//! serving tiers, multi-turn shared-prefix sessions, adversarial floods —
//! using only [`SplitMix64`] integer arithmetic, so the same seed yields
//! a byte-identical trace on every platform. [`run`] replays a trace
//! against a [`Cluster`] in lockstep (one arrival batch + one
//! [`Cluster::step`] per simulated step), optionally injecting a replica
//! failure/respawn at fixed steps ([`FaultPlan`]), and returns every
//! request's terminal [`Response`] — a request that never resolves is a
//! hard error, which is what makes "zero lost requests" assertable.

use std::time::Duration;

use crate::api::CompletionRequest;
use crate::cluster::Cluster;
use crate::coordinator::Response;
use crate::error::{Error, Result};
use crate::util::json::{self, Value};
use crate::util::rng::SplitMix64;

/// Word pool for synthetic prompts (the serving tokenizer is byte-level,
/// so prompt *characters* are prompt *tokens*).
const WORDS: &[&str] = &[
    "the", "red", "fox", "jumps", "over", "a", "lazy", "dog", "while", "quick", "brown",
    "packs", "my", "box", "with", "five", "dozen", "jugs", "of", "liquid",
];

/// Longest prompt the generator emits (chars = tokens; well under the
/// td-small context of 256 even with the generation budget added).
const MAX_PROMPT: usize = 120;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// Evenly spaced singleton arrivals.
    Steady,
    /// Geometric bursts separated by quiet gaps.
    Bursty,
    /// Few sessions, many turns each, sharing a long per-session prefix
    /// (exercises session affinity + paged-KV prefix reuse).
    MultiTurn,
    /// Adversarial: everything arrives in the first two steps.
    Flood,
    /// Interleaved chunks of all of the above.
    Mixed,
}

impl Scenario {
    pub const ALL: [Scenario; 5] =
        [Scenario::Steady, Scenario::Bursty, Scenario::MultiTurn, Scenario::Flood, Scenario::Mixed];

    pub fn as_str(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty => "bursty",
            Scenario::MultiTurn => "multiturn",
            Scenario::Flood => "flood",
            Scenario::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Option<Scenario> {
        Scenario::ALL.into_iter().find(|sc| sc.as_str() == s)
    }
}

/// One scheduled request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Lockstep step at which the request hits the front door.
    pub at_step: u64,
    pub prompt: String,
    pub max_tokens: usize,
    pub tier: Option<String>,
    pub session: Option<String>,
}

/// A fully expanded, replayable workload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LoadTrace {
    pub seed: u64,
    pub scenario: Scenario,
    pub arrivals: Vec<Arrival>,
}

impl LoadTrace {
    /// Expand `(scenario, seed)` into `n` arrivals over `tiers` (the
    /// model's registered tier names; an arrival with `tier: None` rides
    /// the default tier).
    pub fn generate(scenario: Scenario, seed: u64, n: usize, tiers: &[String]) -> LoadTrace {
        let mut rng = SplitMix64::new(seed ^ 0x10ad_9e4e);
        let mut arrivals = Vec::with_capacity(n);
        let mut step: u64 = 0;
        // MultiTurn state: per-session long shared prefix + turn counter
        let sessions = (n / 4).clamp(2, 8);
        let prefixes: Vec<String> =
            (0..sessions).map(|s| session_prefix(s, &mut rng)).collect();
        let mut turns = vec![0usize; sessions];
        let mut i = 0;
        while i < n {
            let sc = match scenario {
                // deterministic round-robin over chunks of 4 arrivals
                Scenario::Mixed => Scenario::ALL[(i / 4) % 4],
                s => s,
            };
            let burst = match sc {
                Scenario::Steady | Scenario::MultiTurn => 1,
                Scenario::Bursty => 1 + rng.below(6) as usize,
                Scenario::Flood => n,
                Scenario::Mixed => unreachable!("Mixed resolves to a concrete scenario"),
            };
            for _ in 0..burst.min(n - i) {
                let (prompt, session) = match sc {
                    Scenario::MultiTurn => {
                        let s = rng.below(sessions as u64) as usize;
                        turns[s] += 1;
                        (
                            format!("{} turn {} {}", prefixes[s], turns[s], word(&mut rng)),
                            Some(format!("sess-{s}")),
                        )
                    }
                    _ => (heavy_tail_prompt(i, &mut rng), None),
                };
                let tier = if tiers.is_empty() || rng.below(5) < 3 {
                    None
                } else {
                    Some(tiers[rng.below(tiers.len() as u64) as usize].clone())
                };
                arrivals.push(Arrival {
                    at_step: step,
                    prompt,
                    max_tokens: 2 + rng.below(7) as usize,
                    tier,
                    session,
                });
                i += 1;
            }
            step += match sc {
                Scenario::Steady => 1 + rng.below(3),
                Scenario::Bursty => 2 + rng.below(8),
                Scenario::MultiTurn => 3 + rng.below(4),
                Scenario::Flood => 1,
                Scenario::Mixed => unreachable!("Mixed resolves to a concrete scenario"),
            };
        }
        LoadTrace { seed, scenario, arrivals }
    }

    /// Canonical JSON rendering — the byte-identity anchor for the
    /// determinism tests and for archiving a replayable workload.
    pub fn to_json(&self) -> String {
        let arrivals: Vec<Value> = self
            .arrivals
            .iter()
            .map(|a| {
                let mut fields = vec![
                    ("at_step", json::num(a.at_step as f64)),
                    ("prompt", json::s(a.prompt.clone())),
                    ("max_tokens", json::num(a.max_tokens as f64)),
                ];
                if let Some(t) = &a.tier {
                    fields.push(("tier", json::s(t.clone())));
                }
                if let Some(s) = &a.session {
                    fields.push(("session", json::s(s.clone())));
                }
                json::obj(fields)
            })
            .collect();
        json::obj(vec![
            ("schema", json::s("truedepth.loadtrace/v1")),
            ("seed", json::num(self.seed as f64)),
            ("scenario", json::s(self.scenario.as_str())),
            ("arrivals", json::arr(arrivals)),
        ])
        .to_string_pretty()
    }
}

/// A long (>= one KV page) session-specific prefix every turn repeats,
/// so consecutive turns hit the shared-prefix index on the affine replica.
fn session_prefix(session: usize, rng: &mut SplitMix64) -> String {
    let mut p = format!("session {session}:");
    while p.len() < 64 {
        p.push(' ');
        p.push_str(word(rng));
    }
    p
}

/// Heavy-tailed prompt length via integer-only geometric escalation
/// (no `powf`/`ln`: byte-identical across platforms). The index prefix
/// keeps prompts distinct so unrelated requests don't share KV prefixes.
fn heavy_tail_prompt(index: usize, rng: &mut SplitMix64) -> String {
    let mut len = 8 + rng.below(16) as usize;
    while rng.below(100) < 35 && len < MAX_PROMPT {
        len += 4 + rng.below(24) as usize;
    }
    let len = len.min(MAX_PROMPT);
    let mut p = format!("q{index}");
    while p.len() < len {
        p.push(' ');
        p.push_str(word(rng));
    }
    p.truncate(len);
    p
}

fn word(rng: &mut SplitMix64) -> &'static str {
    WORDS[rng.below(WORDS.len() as u64) as usize]
}

/// Deterministic fault injection: fence `replica` when the replay clock
/// hits `fail_at_step`, optionally respawn it later.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    pub replica: usize,
    pub fail_at_step: u64,
    pub respawn_at_step: Option<u64>,
}

/// Outcome of one trace replay.
pub struct LoadReport {
    /// Terminal response per arrival, in arrival order; `None` marks a
    /// front-door rejection (queue full — back-pressure, not loss).
    pub responses: Vec<Option<Response>>,
    /// Lockstep steps the replay took to drain.
    pub steps: u64,
}

impl LoadReport {
    pub fn completed(&self) -> usize {
        self.responses.iter().flatten().filter(|r| r.error.is_none()).count()
    }

    pub fn failed(&self) -> usize {
        self.responses.iter().flatten().filter(|r| r.error.is_some()).count()
    }

    pub fn rejected(&self) -> usize {
        self.responses.iter().filter(|r| r.is_none()).count()
    }
}

/// Replay `trace` against `cluster` to completion. Every accepted
/// request must resolve to a terminal response — a request that does not
/// is an `Err` (lost work), not a silent gap in the report.
pub fn run(
    cluster: &mut Cluster,
    trace: &LoadTrace,
    fault: Option<&FaultPlan>,
) -> Result<LoadReport> {
    const MAX_STEPS: u64 = 1_000_000;
    let mut handles = Vec::with_capacity(trace.arrivals.len());
    let mut next = 0usize;
    let mut step: u64 = 0;
    loop {
        if let Some(f) = fault {
            if step == f.fail_at_step {
                cluster.fail_replica(f.replica);
            }
            if f.respawn_at_step == Some(step) {
                cluster.respawn_replica(f.replica)?;
            }
        }
        while next < trace.arrivals.len() && trace.arrivals[next].at_step <= step {
            let a = &trace.arrivals[next];
            let mut req = CompletionRequest::new(&a.prompt).max_tokens(a.max_tokens);
            if let Some(t) = &a.tier {
                req = req.tier(t);
            }
            if let Some(s) = &a.session {
                req = req.session(s);
            }
            handles.push(cluster.submit(req).ok());
            next += 1;
        }
        let busy = cluster.step();
        step += 1;
        if step > MAX_STEPS {
            return Err(Error::Serving(format!(
                "loadtest failed to drain within {MAX_STEPS} steps"
            )));
        }
        let arrivals_pending = next < trace.arrivals.len();
        let fault_pending = fault.is_some_and(|f| {
            f.fail_at_step >= step || f.respawn_at_step.is_some_and(|s| s >= step)
        });
        if !busy && !arrivals_pending && !fault_pending {
            break;
        }
    }
    cluster.finish();
    let mut responses = Vec::with_capacity(handles.len());
    for (i, h) in handles.into_iter().enumerate() {
        match h {
            None => responses.push(None),
            Some(h) => {
                // events are already buffered (the cluster is drained);
                // the timeout only guards against a lost-terminal bug
                let r = h.wait_timeout(Duration::from_secs(10)).map_err(|e| {
                    Error::Serving(format!("request for arrival {i} was lost: {e}"))
                })?;
                responses.push(Some(r));
            }
        }
    }
    Ok(LoadReport { responses, steps: step })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiers() -> Vec<String> {
        vec!["dense".into(), "lp".into(), "lp_aggr".into()]
    }

    /// Satellite: same seed → byte-identical arrival schedule; distinct
    /// seeds diverge. Holds for every scenario.
    #[test]
    fn same_seed_is_byte_identical_and_seeds_differ() {
        for sc in Scenario::ALL {
            let a = LoadTrace::generate(sc, 7, 40, &tiers()).to_json();
            let b = LoadTrace::generate(sc, 7, 40, &tiers()).to_json();
            assert_eq!(a, b, "{}: same seed must replay byte-identically", sc.as_str());
            let c = LoadTrace::generate(sc, 8, 40, &tiers()).to_json();
            assert_ne!(a, c, "{}: distinct seeds must differ", sc.as_str());
        }
    }

    #[test]
    fn schedules_are_ordered_and_bounded() {
        for sc in Scenario::ALL {
            let t = LoadTrace::generate(sc, 3, 64, &tiers());
            assert_eq!(t.arrivals.len(), 64);
            let mut prev = 0;
            for a in &t.arrivals {
                assert!(a.at_step >= prev, "{}: arrivals must be time-ordered", sc.as_str());
                prev = a.at_step;
                assert!(!a.prompt.is_empty());
                assert!(
                    a.prompt.len() + a.max_tokens <= MAX_PROMPT + 8,
                    "{}: prompt+budget must fit the context", sc.as_str()
                );
                if let Some(tier) = &a.tier {
                    assert!(tiers().contains(tier));
                }
            }
        }
    }

    #[test]
    fn flood_is_front_loaded_and_steady_is_not() {
        let flood = LoadTrace::generate(Scenario::Flood, 5, 32, &[]);
        assert!(flood.arrivals.iter().all(|a| a.at_step == 0));
        let steady = LoadTrace::generate(Scenario::Steady, 5, 32, &[]);
        assert!(steady.arrivals.last().unwrap().at_step >= 31);
    }

    #[test]
    fn multiturn_sessions_share_long_prefixes() {
        let t = LoadTrace::generate(Scenario::MultiTurn, 9, 48, &tiers());
        let mut by_session: std::collections::BTreeMap<&str, Vec<&Arrival>> = Default::default();
        for a in &t.arrivals {
            by_session.entry(a.session.as_deref().expect("multiturn always has a session"))
                .or_default()
                .push(a);
        }
        assert!(by_session.len() >= 2, "need several concurrent sessions");
        let mut multi_turn_sessions = 0;
        for arrivals in by_session.values() {
            if arrivals.len() < 2 {
                continue;
            }
            multi_turn_sessions += 1;
            let first = &arrivals[0].prompt;
            for a in &arrivals[1..] {
                let common = first
                    .bytes()
                    .zip(a.prompt.bytes())
                    .take_while(|(x, y)| x == y)
                    .count();
                assert!(
                    common >= 64,
                    "turns of one session must share a >=1-page prefix (got {common})"
                );
            }
        }
        assert!(multi_turn_sessions >= 1, "at least one session must have several turns");
    }

    #[test]
    fn heavy_tail_produces_short_and_long_prompts() {
        let t = LoadTrace::generate(Scenario::Bursty, 11, 200, &[]);
        let lens: Vec<usize> = t.arrivals.iter().map(|a| a.prompt.len()).collect();
        assert!(lens.iter().any(|&l| l < 32), "tail must keep short prompts");
        assert!(lens.iter().any(|&l| l > 90), "tail must reach long prompts");
        assert!(lens.iter().all(|&l| l <= MAX_PROMPT));
    }

    #[test]
    fn scenario_names_round_trip() {
        for sc in Scenario::ALL {
            assert_eq!(Scenario::parse(sc.as_str()), Some(sc));
        }
        assert_eq!(Scenario::parse("warp"), None);
    }
}
