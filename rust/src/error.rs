//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {at}: {msg}")]
    Json { at: usize, msg: String },

    #[error("config: {0}")]
    Config(String),

    #[error("weights: {0}")]
    Weights(String),

    #[error("artifact `{0}` not found in manifest")]
    MissingArtifact(String),

    #[error("invalid graph plan: {0}")]
    Plan(String),

    #[error("serving: {0}")]
    Serving(String),

    #[error("verify: {0}")]
    Verify(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
