//! Crate-wide error type.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum Error {
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("json parse error at byte {at}: {msg}")]
    Json { at: usize, msg: String },

    #[error("config: {0}")]
    Config(String),

    #[error("weights: {0}")]
    Weights(String),

    #[error("artifact `{0}` not found in manifest")]
    MissingArtifact(String),

    #[error("invalid graph plan: {0}")]
    Plan(String),

    #[error("serving: {0}")]
    Serving(String),

    /// Client-side request errors: malformed wire payloads and admission
    /// bounds the caller can fix (prompt/budget limits). Maps to HTTP 400
    /// via `api::ErrorCode::InvalidRequest`.
    #[error("bad request: {0}")]
    BadRequest(String),

    /// A serving tier the model's manifest does not carry. Names the
    /// available tiers so the caller can pick one; maps to HTTP 404 via
    /// `api::ErrorCode::UnknownTier`.
    #[error("tier `{tier}` not served by this model (manifest variants: {available})")]
    UnknownTier { tier: String, available: String },

    /// Transient capacity exhaustion (queue back-pressure, page pools):
    /// the request may succeed later unchanged. Maps to HTTP 429 via
    /// `api::ErrorCode::Overloaded`.
    #[error("overloaded: {0}")]
    Overloaded(String),

    #[error("verify: {0}")]
    Verify(String),

    #[error("{0}")]
    Msg(String),
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
