//! # truedepth — Layer Parallelism for LLM inference
//!
//! Rust coordinator for the three-layer reproduction of *"Leveraging the
//! true depth of LLMs"* (2025). The paper's contribution — running pairs of
//! consecutive transformer layers in parallel under tensor parallelism,
//! halving the all-reduce count — lives here as a first-class serving
//! feature:
//!
//! * [`parallel`] — the simulated multi-accelerator runtime: worker threads
//!   owning AOT-compiled PJRT executables, collectives with an α–β
//!   interconnect cost model.
//! * [`model`] — weights, the computational-graph transform engine
//!   (shuffle / prune / merge / parallel / 2-parallel), the scoring
//!   executor and the TP/LP serving executor with KV-slot caches.
//! * [`coordinator`] — request router, continuous batcher and
//!   prefill/decode scheduler (vLLM-router shaped).
//! * [`cluster`] — multi-replica serving: a cost-model router over R
//!   independent meshes, session affinity, replica drain/respawn, and a
//!   deterministic trace-driven load harness (`truedepth loadtest`).
//! * [`runtime`] — PJRT client + artifact manifest loading (HLO text AOT'd
//!   by `python/compile/aot.py`; python never runs at request time).
//! * [`eval`] — perplexity + the synthetic 5-shot ICL suite.
//! * [`verify`] — static plan/binding/collective checker over the artifact
//!   manifest: runs at load time, as `truedepth verify`, and as a CI gate.
//! * [`obs`] — deterministic tracing + metrics export on the simulated
//!   clock: Chrome/Perfetto traces and machine-readable snapshots.
//! * [`api`] — the typed request/response schema (completions wire format,
//!   stable error codes) shared by the in-process path and the HTTP edge.
//! * [`serve`] — std-only HTTP/1.1 front-end: `truedepth serve --listen`
//!   streams tokens as SSE and sheds overload before any slot is claimed.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for results.

pub mod api;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod eval;
pub mod gen;
pub mod harness;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod profiling;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod text;
pub mod util;
pub mod verify;

pub use error::{Error, Result};

/// Repository root discovery: honors `TRUEDEPTH_ROOT`, else walks up from
/// the current directory until it finds `artifacts/manifest.json` (or a
/// `Cargo.toml` as a fallback for test runs).
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(r) = std::env::var("TRUEDEPTH_ROOT") {
        return r.into();
    }
    let mut dir = std::env::current_dir().expect("cwd");
    loop {
        if dir.join("artifacts/manifest.json").exists() || dir.join("Cargo.toml").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().expect("cwd");
        }
    }
}
