//! Table 2 regenerator: benchmark-accuracy restoration through fine-tuning
//! of the LP window. The checkpoints come from `make finetune` (python,
//! build-time); this binary evaluates each against the deployed LP plan.
//!
//!     make finetune            # trains td-small-lpft{64,256,1024}
//!     cargo run --release --bin table2_finetune [-- --samples 30]
//!
//! Output: results/table2.csv (ft_steps, relation[MMLU-ish], pattern[ArcC-ish],
//! arith[GSM-8K-ish], avg) — rows: 0 steps (raw LP), each fine-tune budget,
//! plus the untransformed base model reference.

use truedepth::cli::Args;
use truedepth::eval::icl::{task_accuracy, IclTask};
use truedepth::harness::{write_csv, ScoringCtx};
use truedepth::model::{transform, Scorer};

const LP_START: usize = 2; // must match Makefile's finetune window
const LP_END: usize = 10;

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "td-small");
    let samples = args.get_usize("samples", 30);

    let ctx = ScoringCtx::load(model)?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let lp_plan = transform::pair_parallel(n, LP_START, LP_END, true);
    let seq_plan = transform::sequential(n);
    let tasks = [IclTask::Relation, IclTask::Pattern, IclTask::Arith];

    let mut rows = Vec::new();
    println!(
        "{:<18} {:>10} {:>10} {:>10} {:>8}",
        "checkpoint", "relation", "pattern", "arith", "avg"
    );
    let mut eval_one = |label: &str, ckpt: &str, plan: &truedepth::model::GraphPlan| -> truedepth::Result<()> {
        let Ok(weights) = ctx.weights_from(ckpt) else {
            println!("{label:<18} (checkpoint missing — run `make finetune`)");
            return Ok(());
        };
        let s128 = Scorer::new(&ctx.engine, entry, &weights, 128)?;
        let s256 = Scorer::new(&ctx.engine, entry, &weights, 256)?;
        let scorers = [&s128, &s256];
        let mut accs = Vec::new();
        for t in tasks {
            accs.push(task_accuracy(&scorers, plan, t, 5, samples, 77)?);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        println!(
            "{label:<18} {:>10.4} {:>10.4} {:>10.4} {avg:>8.4}",
            accs[0], accs[1], accs[2]
        );
        rows.push(format!("{label},{:.4},{:.4},{:.4},{avg:.4}", accs[0], accs[1], accs[2]));
        Ok(())
    };

    eval_one("0 (Ours)", model, &lp_plan)?;
    for steps in [64usize, 256, 1024] {
        eval_one(&format!("{steps} (Ours)"), &format!("{model}-lpft{steps}"), &lp_plan)?;
    }
    eval_one("Base (seq)", model, &seq_plan)?;

    write_csv("table2.csv", "ft_steps,relation,pattern,arith,avg", &rows);
    Ok(())
}
