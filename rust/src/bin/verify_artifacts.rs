//! CI artifact-verification gate (the `verify` job).
//!
//!     cargo run --release --bin verify_artifacts [-- --artifacts DIR] [--lenient]
//!
//! Runs the full static verification pass (`truedepth::verify`) over the
//! AOT artifact manifest the python `compile.aot` job produced: plan
//! coverage/adjacency/executable consistency, abstract-interpretation
//! binding analysis of every variant's dispatch sequence, and MPI-style
//! collective matching across ranks. Strict by default — artifact files
//! must exist on disk and *warnings fail the gate* (a shipped manifest
//! should carry zero findings); `--lenient` downgrades to the same policy
//! `Manifest::load` applies at serve time (errors only, no file checks).
//!
//! Exit status is the gate: 0 = manifest verified, 1 = findings (all of
//! them printed, not just the first).

use truedepth::cli::Args;

fn main() {
    let args = Args::from_env(&["lenient", "help"]);
    let dir = match args.get("artifacts") {
        Some(p) => std::path::PathBuf::from(p),
        None => truedepth::repo_root().join("artifacts"),
    };
    if let Err(e) = truedepth::verify::run_cli(&dir, !args.flag("lenient")) {
        eprintln!("verify_artifacts: {e}");
        std::process::exit(1);
    }
}
