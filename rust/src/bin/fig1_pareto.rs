//! Fig. 1 regenerator: the time-vs-perplexity Pareto view. For both models
//! and a sweep of LP depths, measures (a) wall-clock to generate a fixed
//! token budget through the tensor-parallel serving path (calibrated α–β
//! interconnect) and (b) held-out perplexity of the same plan.
//!
//! The paper's headline: the bigger model WITH LP beats the smaller model
//! without it on both axes simultaneously.
//!
//!     cargo run --release --bin fig1_pareto [-- --gen-tokens 48 --windows 2]
//!
//! Output: results/fig1.csv (model, eff_depth, delta, gen_ms, ppl).

use truedepth::cli::Args;
use truedepth::eval::ppl::{eval_windows, perplexity};
use truedepth::gen::{generate, Sampler};
use truedepth::harness::{default_net, write_csv, ScoringCtx};
use truedepth::model::{transform, Scorer, ServingModel};
use truedepth::text::corpus::DATA_SEED;

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let gen_tokens = args.get_usize("gen-tokens", 48);
    let n_windows = args.get_usize("windows", 2);
    let bucket = 128;

    let mut rows = Vec::new();
    for model in ["td-small", "td-base"] {
        let ctx = ScoringCtx::load(model)?;
        let Ok(weights) = ctx.weights() else {
            println!("({model}: no checkpoint, skipping)");
            continue;
        };
        let entry = ctx.entry();
        let n = entry.config.n_layers;
        let scorer = Scorer::new(&ctx.engine, entry, &weights, bucket)?;
        let windows = eval_windows(bucket, n_windows, DATA_SEED);
        let end = n - 2;

        for depth in (n / 2 + 2..=n).rev() {
            let plan = if depth == n {
                transform::sequential(n)
            } else {
                match transform::lp_for_depth(n, depth, end) {
                    Some(p) => p,
                    None => continue,
                }
            };
            let ppl = perplexity(&scorer, &plan, &windows)?;
            let serving =
                ServingModel::new(&ctx.manifest, model, &weights, &plan, default_net())?;
            // warm-up + measured generation
            let _ = generate(&serving, "the red fox", 4, &Sampler::Greedy)?;
            let g = generate(&serving, "the capital of avaria is", gen_tokens, &Sampler::Greedy)?;
            let total_ms = g.prefill_ms + g.decode_ms;
            println!(
                "{model:<9} depth {depth:>2} Δ{:<2}  gen {gen_tokens} tok: {total_ms:>8.1} ms   ppl {ppl:.3}",
                plan.delta()
            );
            rows.push(format!("{model},{depth},{},{total_ms:.2},{ppl:.4}", plan.delta()));
        }
    }
    write_csv("fig1.csv", "model,eff_depth,delta,gen_ms,ppl", &rows);
    Ok(())
}
