//! Table 1 regenerator: 5-shot ICL accuracies vs effective depth.
//!
//!     cargo run --release --bin table1_icl [-- --model td-small \
//!         --samples 25 --end <idx> --min-depth <d>]
//!
//! For each effective depth (base N down to the deepest LP window that
//! fits), applies contiguous 2-parallel pairs ending at `--end` (default
//! n_layers - 2, the Fig.6-style optimum) and evaluates the synthetic ICL
//! suite. Output: results/table1_<model>.csv + a formatted table matching
//! the paper's layout (depth × task accuracies + average).

use truedepth::cli::Args;
use truedepth::eval::icl::{evaluate_suite, ALL_TASKS};
use truedepth::harness::{write_csv, ScoringCtx};
use truedepth::model::{transform, Scorer};

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "td-small");
    let samples = args.get_usize("samples", 25);
    let k = args.get_usize("shots", 5);

    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let end = args.get_usize("end", n - 2);
    let min_depth = args.get_usize("min-depth", n - end / 2);

    let s128 = Scorer::new(&ctx.engine, entry, &weights, 128)?;
    let s256 = Scorer::new(&ctx.engine, entry, &weights, 256)?;
    let scorers = [&s128, &s256];

    println!("model {model} ({} layers), LP windows ending at {end}", n);
    let mut header = vec!["eff_depth".to_string(), "delta".to_string()];
    header.extend(ALL_TASKS.iter().map(|t| t.name().to_string()));
    header.push("avg".to_string());

    let mut rows = Vec::new();
    println!(
        "{:<10} {:<6} {}  avg",
        "eff.depth",
        "Δ",
        ALL_TASKS.map(|t| format!("{:>9}", t.name())).join(" ")
    );
    for depth in (min_depth..=n).rev() {
        let plan = if depth == n {
            transform::sequential(n)
        } else {
            match transform::lp_for_depth(n, depth, end) {
                Some(p) => p,
                None => continue,
            }
        };
        let report = evaluate_suite(&scorers, &plan, k, samples, 20260711)?;
        let accs: Vec<String> =
            report.per_task.iter().map(|(_, a)| format!("{a:.4}")).collect();
        let label = if depth == n { format!("{depth} (Base)") } else { format!("{depth} (Ours)") };
        println!(
            "{label:<10} {:<6} {}  {:.4}",
            plan.delta(),
            report.per_task.iter().map(|(_, a)| format!("{a:>9.4}")).join(" "),
            report.average()
        );
        rows.push(format!(
            "{depth},{},{},{:.4}",
            plan.delta(),
            accs.join(","),
            report.average()
        ));
    }
    write_csv(&format!("table1_{model}.csv"), &header.join(","), &rows);
    Ok(())
}

trait JoinExt {
    fn join(self, sep: &str) -> String;
}

impl<I: Iterator<Item = String>> JoinExt for I {
    fn join(self, sep: &str) -> String {
        self.collect::<Vec<_>>().join(sep)
    }
}
