//! Fig. 7 regenerator from **modelled time**: the throughput-vs-depth
//! (throughput-vs-accuracy proxy) tradeoff curve of Layer Parallelism,
//! computed analytically from the unified cost model — no GPU, no
//! artifacts, fully deterministic.
//!
//!     cargo run --release --bin fig7_modelled [-- --model llama7b|td-small]
//!
//! For each LP window size Δ (depth n − Δ/2) the decode-token cost is the
//! sum of the `parallel::simnet::CostModel` terms over the serving
//! executor's protocol shape (mirrors `ServingModel::decode_step_shaped`):
//!
//! * roofline compute: `B · decode_flops_per_lane` flops over
//!   `decode_bytes(B)` bytes (weights stream once per round, K/V per lane);
//! * `2 + 2·stages` kernel launches (embed + 2 per stage + logits);
//! * `2·stages` all-reduces of the `[B, D]` f32 partial (α–β);
//! * host link: token ids + positions (+ lane map) in, embed shadow +
//!   `[B, V]` logits out.
//!
//! The `llama7b` preset prices Llama-2-7B shapes on an A100-like
//! [`DeviceProfile`] with α calibrated so modelled sync:compute matches the
//! paper's Table 3 ratio (100.8 : 217 ≈ 0.46); at full LP coverage the
//! modelled speedup lands at the paper's headline ≈1.19× (printed, and
//! loosely asserted whenever the binary runs — it is not yet wired into a
//! CI job; see the ROADMAP follow-up). The accuracy axis
//! of the paper's figure is proxied by the depth fraction here — pair with
//! `fig6_ppl_sweep` for measured td-small perplexity at each depth.
//!
//! Output: results/fig7_modelled_<model>.csv
//!   (task, delta, eff_depth, depth_fraction, occupancy,
//!    modelled_ms_per_tok, tok_per_s, speedup_vs_d0)

use truedepth::cli::Args;
use truedepth::config::{DeviceProfile, InterconnectConfig};
use truedepth::harness::write_csv;
use truedepth::model::plan::{GraphPlan, Stage};
use truedepth::model::transform;
use truedepth::parallel::CostModel;
use truedepth::runtime::buckets::{decode_bytes, decode_flops_per_lane};
use truedepth::runtime::ModelConfig;

const RANKS: usize = 2;

struct Preset {
    cfg: ModelConfig,
    cost: CostModel,
}

fn preset(name: &str) -> Option<Preset> {
    match name {
        // The testbed model priced with the calibrated testbed defaults.
        "td-small" => Some(Preset {
            cfg: ModelConfig {
                name: "td-small".into(),
                vocab: 260,
                d_model: 128,
                n_layers: 12,
                n_heads: 4,
                head_dim: 32,
                d_ff: 256,
                ctx: 256,
                slots: 4,
            },
            cost: CostModel::from_net(InterconnectConfig::default()),
        }),
        // Llama-2 7B shapes on an A100-like profile. α is calibrated so
        // modelled sync:compute for full-depth TP decode sits at the
        // paper's Table 3 ratio (≈0.46); β/peak/HBM are public A100 specs
        // (f32 traffic, hence the conservative HBM figure).
        "llama7b" => Some(Preset {
            cfg: ModelConfig {
                name: "llama7b".into(),
                vocab: 32000,
                d_model: 4096,
                n_layers: 32,
                n_heads: 32,
                head_dim: 128,
                d_ff: 11008,
                ctx: 4096,
                slots: 4,
            },
            cost: CostModel::new(
                InterconnectConfig {
                    alpha_s: 115e-6,
                    beta_bytes_per_s: 300e9,
                    enabled: true,
                },
                DeviceProfile {
                    peak_flops_per_s: 312e12,
                    hbm_bytes_per_s: 1.9e12,
                    launch_s: 5e-6,
                    host_bytes_per_s: 25e9,
                },
            ),
        }),
        _ => None,
    }
}

/// Layer-equivalents of a serving plan (Tp = 1 whole layer of compute
/// across the mesh, Lp = 2) — mirrors `ServingModel::new`.
fn layers_equiv(plan: &GraphPlan) -> usize {
    plan.stages
        .iter()
        .map(|s| match s {
            Stage::Seq(_) => 1,
            Stage::PairLp(..) => 2,
            _ => unreachable!("fig7_modelled sweeps only servable plans"),
        })
        .sum()
}

/// Modelled wall time of one decode round over `b` dispatched lanes,
/// in seconds (the protocol shape documented in the module docs).
fn decode_round_s(cost: &CostModel, cfg: &ModelConfig, plan: &GraphPlan, b: usize) -> f64 {
    let stages = plan.stages.len();
    let le = layers_equiv(plan);
    let d = cfg.d_model;
    let compute = cost
        .compute_cost(b as u64 * decode_flops_per_lane(cfg, le), decode_bytes(cfg, le, b));
    let launches = cost.launch_cost(2 + 2 * stages as u64);
    let sync_one = cost.all_reduce_cost(b * d * 4, RANKS);
    let host_bytes = (RANKS * b * 4)      // positions, uploaded per rank
        + (RANKS * b * 4)                 // lane map, uploaded per rank
        + b * 4                           // token ids (rank-0 embed arg)
        + b * d * 4                       // embed shadow download
        + b * cfg.vocab * 4; // [B, V] logits download
    let host = cost.host_transfer_cost(host_bytes as u64);
    compute.as_secs_f64()
        + launches.as_secs_f64()
        + 2.0 * stages as f64 * sync_one.as_secs_f64()
        + host.as_secs_f64()
}

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "llama7b");
    let Some(p) = preset(model) else {
        return Err(truedepth::Error::msg(format!(
            "fig7_modelled: unknown preset `{model}` (llama7b | td-small)"
        )));
    };
    let n = p.cfg.n_layers;

    // Δ sweep: 0 (sequential TP) up to full pair-parallel coverage.
    let mut rows = Vec::new();
    let mut headline = None;
    let mut base: std::collections::HashMap<(String, usize), f64> =
        std::collections::HashMap::new();
    println!("== fig7 (modelled) — {model}, {n} layers ==");
    for delta in (0..=n).step_by(4) {
        let plan = if delta == 0 {
            transform::sequential(n)
        } else {
            match transform::lp_for_depth(n, n - delta / 2, n) {
                Some(p) => p,
                None => continue,
            }
        };
        let depth = plan.effective_depth();
        let frac = depth as f64 / n as f64;
        for (task, b) in [("one_token", 1usize), ("batch_decode", p.cfg.slots)] {
            let secs = decode_round_s(&p.cost, &p.cfg, &plan, b);
            let ms = secs * 1e3;
            let tps = b as f64 / secs;
            let key = (task.to_string(), b);
            if delta == 0 {
                base.insert(key.clone(), ms);
            }
            let speedup = base.get(&key).map(|m0| m0 / ms).unwrap_or(1.0);
            println!(
                "  Δ={delta:<3} depth {depth:<3} {task:<12} B={b}: {ms:>8.3} ms/round  {tps:>9.1} tok/s  ×{speedup:.3}"
            );
            rows.push(format!(
                "{task},{delta},{depth},{frac:.4},{b},{ms:.4},{tps:.2},{speedup:.4}"
            ));
            if task == "one_token" && delta == n {
                headline = Some(speedup);
            }
        }
    }

    if let Some(x) = headline {
        println!(
            "\nheadline: full-LP single-stream decode speedup ×{x:.3} (paper: ×1.19 on Llama 2 7B)"
        );
        if model == "llama7b" {
            // Loose envelope: the calibration should keep the modelled
            // headline in the paper's neighborhood; a drift outside it
            // means the cost model or the protocol shape changed.
            assert!(
                (1.05..1.40).contains(&x),
                "modelled llama7b speedup ×{x:.3} left the paper's neighborhood"
            );
        }
    }

    write_csv(
        &format!("fig7_modelled_{model}.csv"),
        "task,delta,eff_depth,depth_fraction,occupancy,modelled_ms_per_tok,tok_per_s,speedup_vs_d0",
        &rows,
    );
    Ok(())
}
