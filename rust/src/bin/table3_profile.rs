//! Table 3 / Fig. 10 regenerator: sync-vs-compute breakdown of two
//! consecutive decoder layers under vanilla TP vs Layer Parallelism.
//!
//!     cargo run --release --bin table3_profile [-- --model td-small \
//!         --steps 50 --seqlen 128 --trace-out table3.trace.json]
//!
//! Runs `--steps` decode iterations over a 2-layer sub-model in each mode
//! and reports total / sync / compute time plus the ratios the paper
//! highlights (sync ≈ ×2 reduction, compute ≈ flat, total ≈ ×1.2).
//! Output: results/table3_<model>.csv, plus a hottest-first wall-clock
//! phase profile (results/table3_phases_<model>.json). With --trace-out
//! the per-tier sweep also exports a Chrome/Perfetto trace of its
//! simulated-clock timeline, making the sync/compute split visible as a
//! timeline instead of a CSV (README "Observability").

use truedepth::cli::Args;
use truedepth::harness::{default_net, results_dir, write_csv, ScoringCtx};
use truedepth::model::plan::{GraphPlan, Stage};
use truedepth::model::{ServingModel, Weights};
use truedepth::obs::{Tracer, Track};
use truedepth::parallel::MeshMetrics;
use truedepth::profiling::PhaseTimer;

/// The deterministic modelled-clock split (sync, compute, host, total), ns.
/// Read as deltas so the per-tier sweep can keep one monotone timeline
/// (resetting the clock mid-trace would fold the timestamps over).
fn modelled_split_ns(m: &MeshMetrics) -> (u64, u64, u64, u64) {
    use std::sync::atomic::Ordering::Relaxed;
    (
        m.modelled_sync_ns.load(Relaxed),
        m.modelled_compute_ns.load(Relaxed),
        m.modelled_host_ns.load(Relaxed),
        m.modelled_total_ns(),
    )
}

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "td-small");
    let steps = args.get_usize("steps", 50);
    let seqlen = args.get_usize("seqlen", 128);
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    let mut timer = PhaseTimer::new();

    let ctx = ScoringCtx::load(model)?;
    let entry = ctx.entry();
    let cfg = entry.config.clone();
    let weights = ctx.weights().unwrap_or_else(|_| Weights::random(&cfg, 3));

    // Two consecutive middle layers, as in the paper's appendix C.
    let (a, b) = (cfg.n_layers / 2, cfg.n_layers / 2 + 1);
    let tp_plan = GraphPlan { n_layers: cfg.n_layers, stages: vec![Stage::Seq(a), Stage::Seq(b)] };
    let lp_plan = GraphPlan { n_layers: cfg.n_layers, stages: vec![Stage::PairLp(a, b)] };

    let mut rows = Vec::new();
    let mut results = Vec::new();
    let guard = timer.start("tp_vs_lp_sweep");
    for (name, plan) in [("tensor_parallel", &tp_plan), ("layer_parallel", &lp_plan)] {
        let serving = ServingModel::new(&ctx.manifest, model, &weights, plan, default_net())?;
        // prefill a cache so decode attends over `seqlen` positions
        let prompt: Vec<i32> = (0..seqlen as i32).map(|i| 97 + (i % 26)).collect();
        serving.prefill(0, &prompt)?;
        // warmup
        let tok = vec![65i32; cfg.slots];
        let pos = vec![seqlen as i32; cfg.slots];
        for _ in 0..3 {
            serving.decode_step(&tok, &pos)?;
        }
        serving.mesh.metrics.reset();
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            serving.decode_step(&tok, &pos)?;
        }
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (sync_ops, sync_ms, compute_ms, _) = serving.mesh.metrics.snapshot();
        let host = serving.mesh.metrics.host_transfers();
        let host_per_tok = host.ops() as f64 / steps as f64;
        // Modelled device compute per token (deterministic; scales with
        // the dispatched batch shape — full [S] lanes here).
        let mflop_per_tok = serving.mesh.metrics.modelled_flops() as f64 / steps as f64 / 1e6;
        // The modelled timeline (deterministic, per token): α–β sync +
        // roofline compute/launches + host link = the simulated clock.
        let m_sync = serving.mesh.metrics.modelled_sync_ms() / steps as f64;
        let m_comp = serving.mesh.metrics.modelled_compute_ms() / steps as f64;
        let m_host = serving.mesh.metrics.modelled_host_ms() / steps as f64;
        let m_total = serving.mesh.metrics.modelled_total_ms() / steps as f64;
        println!(
            "{name:<16}: total {total_ms:>8.2} ms  sync {sync_ms:>8.2} ms ({sync_ops} ops)  compute {compute_ms:>8.2} ms ({mflop_per_tok:.2} Mflop/tok)  host xfers/tok {host_per_tok:.1}"
        );
        println!(
            "{:<16}  modelled/tok: total {m_total:>7.3} ms = sync {m_sync:.3} + compute {m_comp:.3} + host {m_host:.4}",
            ""
        );
        rows.push(format!(
            "{name},{total_ms:.2},{sync_ms:.2},{compute_ms:.2},{sync_ops},{host_per_tok:.1},{mflop_per_tok:.2},{m_sync:.4},{m_comp:.4},{m_host:.4},{m_total:.4}"
        ));
        results.push((m_total, m_sync, m_comp, sync_ops));
    }
    drop(guard);

    // Shape-bucket dispatch: the same 2-layer LP sub-model at occupancy 1
    // bills the B=1 bucket — device compute and the logits download drop
    // to 1/S of the full-batch round above.
    {
        let _g = timer.start("occupancy_1");
        let serving = ServingModel::new(&ctx.manifest, model, &weights, &lp_plan, default_net())?;
        let prompt: Vec<i32> = (0..seqlen as i32).map(|i| 97 + (i % 26)).collect();
        serving.prefill(0, &prompt)?;
        serving.mesh.metrics.reset();
        serving.decode_active(&[(0, 65, seqlen as i32)])?;
        let flops = serving.mesh.metrics.modelled_flops();
        let out = serving.mesh.metrics.host_transfers().out_bytes;
        println!(
            "occupancy 1/{}   : modelled {:.2} Mflop/tok  download {out} B  {:.3} ms modelled/tok  (buckets {:?})",
            cfg.slots,
            flops as f64 / 1e6,
            serving.mesh.metrics.modelled_total_ms(),
            serving.bucket_set().buckets(),
        );
    }

    // Plan-variant registry: the per-tier sync/compute split over the FULL
    // serving plans (not the 2-layer sub-model above) — one weight set,
    // one manifest, each tier priced at its own depth. The sync column is
    // where the tiers diverge (2 all-reduces per stage); the flop term of
    // compute stays flat because every tier runs the same layer-equivalents
    // — exactly the paper's Table 3 shape, now as a per-request dial.
    if let Ok(tiers) = ServingModel::from_manifest(&ctx.manifest, model, &weights, default_net())
    {
        let _g = timer.start("tier_sweep");
        let profile_steps = steps.min(10);
        println!("\nper-tier modelled split ({profile_steps} decode rounds, full plans):");
        if trace_out.is_some() {
            tiers.mesh.begin_trace();
        }
        let mut trows = Vec::new();
        let mut tier_spans: Vec<(String, u64, u64)> = Vec::new();
        for vid in tiers.variant_ids() {
            let prompt: Vec<i32> = (0..seqlen as i32).map(|i| 97 + (i % 26)).collect();
            tiers.prefill_v(&vid, 0, &prompt)?;
            tiers.decode_active_v(&vid, &[(0, 65, seqlen as i32)])?; // warm
            // Delta-based accounting (no reset): the simulated clock keeps
            // running across tiers, so --trace-out sees one monotone
            // timeline while the per-tier figures stay identical.
            let (s0, c0, h0, clk0) = modelled_split_ns(&tiers.mesh.metrics);
            for _ in 0..profile_steps {
                tiers.decode_active_v(&vid, &[(0, 65, seqlen as i32)])?;
            }
            let (s1, c1, h1, clk1) = modelled_split_ns(&tiers.mesh.metrics);
            let n = profile_steps as f64;
            let m_sync = (s1 - s0) as f64 / 1e6 / n;
            let m_comp = (c1 - c0) as f64 / 1e6 / n;
            let m_host = (h1 - h0) as f64 / 1e6 / n;
            let m_total = (clk1 - clk0) as f64 / 1e6 / n;
            tier_spans.push((vid.to_string(), clk0, clk1));
            let var = tiers.variant(&vid)?;
            println!(
                "tier {:<8} depth {:>2} ({:>2} reduces/tok): total {m_total:>7.3} ms = sync {m_sync:.3} + compute {m_comp:.3} + host {m_host:.4}",
                vid.to_string(),
                var.effective_depth(),
                var.all_reduces_per_token(),
            );
            trows.push(format!(
                "{vid},{},{},{m_sync:.4},{m_comp:.4},{m_host:.4},{m_total:.4}",
                var.effective_depth(),
                var.all_reduces_per_token(),
            ));
        }
        write_csv(
            &format!("table3_tiers_{model}.csv"),
            "tier,effective_depth,all_reduces_per_token,modelled_sync_ms_per_tok,modelled_compute_ms_per_tok,modelled_host_ms_per_tok,modelled_total_ms_per_tok",
            &trows,
        );
        // --trace-out: the tier sweep as a Chrome/Perfetto timeline — one
        // span per tier's profiled window on its own track, over the mesh
        // track's per-dispatch events (see README "Observability").
        if let Some(path) = &trace_out {
            let tracer = Tracer::new();
            tracer.record_mesh_events(tiers.mesh.take_timed_trace());
            for (vid, a, b) in &tier_spans {
                tracer.span(
                    Track::Tier(vid.clone()),
                    format!("profile {vid}"),
                    *a,
                    *b,
                    &[("tier", vid.clone())],
                );
            }
            tracer.write_chrome(path)?;
            println!("tier-sweep trace: {} ({} events)", path.display(), tracer.len());
        }
    }

    // Chunked streaming prefill: modelled prefill flops scale with
    // ceil(L / chunk) chunk steps instead of the covering seq bucket T.
    {
        let _g = timer.start("chunked_prefill");
        let serving = ServingModel::new(&ctx.manifest, model, &weights, &lp_plan, default_net())?;
        if let Some(k) = serving.prefill_chunk() {
            let mut prows = Vec::new();
            for l in [16usize, 72, 136, 224] {
                let prompt: Vec<i32> = (0..l as i32).map(|i| 97 + (i % 26)).collect();
                serving.mesh.metrics.reset();
                serving.prefill(0, &prompt)?;
                let mono = serving.mesh.metrics.modelled_flops();
                serving.mesh.metrics.reset();
                serving.prefill_chunked(0, &prompt)?;
                let chunked = serving.mesh.metrics.modelled_flops();
                let m_ms = serving.mesh.metrics.modelled_total_ms();
                println!(
                    "prefill L={l:>3}   : monolithic {:>7.2} Mflop vs chunked {:>7.2} Mflop ({} chunks of {k}, {m_ms:.3} ms modelled)",
                    mono as f64 / 1e6,
                    chunked as f64 / 1e6,
                    l.div_ceil(k),
                );
                prows.push(format!(
                    "{l},{k},{},{:.4},{:.4},{m_ms:.4}",
                    l.div_ceil(k),
                    mono as f64 / 1e6,
                    chunked as f64 / 1e6
                ));
            }
            write_csv(
                &format!("table3_prefill_{model}.csv"),
                "prompt_len,chunk,chunks,monolithic_mflop,chunked_mflop,chunked_modelled_ms",
                &prows,
            );
        }
    }

    let (t_tp, s_tp, c_tp, o_tp) = results[0];
    let (t_lp, s_lp, c_lp, o_lp) = results[1];
    println!("\npaper Table 3 shape (TP/LP ratios, modelled — deterministic):");
    println!("  sync ops : {o_tp} → {o_lp} (×{:.2}; paper ×2.00)", o_tp as f64 / o_lp as f64);
    println!("  sync ms  : ×{:.2}  (paper ×1.99)", s_tp / s_lp);
    println!("  compute  : ×{:.2}  (paper ×1.04)", c_tp / c_lp);
    println!("  total    : ×{:.2}  (paper ×1.23)", t_tp / t_lp);

    write_csv(
        &format!("table3_{model}.csv"),
        "approach,total_ms,sync_ms,compute_ms,sync_ops,host_transfers_per_token,mflop_per_token,modelled_sync_ms_per_tok,modelled_compute_ms_per_tok,modelled_host_ms_per_tok,modelled_total_ms_per_tok",
        &rows,
    );

    // Wall-clock phase breakdown (hottest section first) as a
    // machine-readable artifact, via PhaseTimer::to_json().
    let ppath = results_dir().join(format!("table3_phases_{model}.json"));
    std::fs::write(&ppath, timer.to_json().to_string_pretty() + "\n")?;
    println!("phase profile (hottest first): {}", ppath.display());
    print!("{}", timer.report());
    Ok(())
}
