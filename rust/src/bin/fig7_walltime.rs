//! Fig. 7 + Fig. 8 regenerator: wall-clock (and tokens/sec) for the three
//! inference tasks — KV-cache prefill, autoregressive generation, and
//! single-token generation with a prefilled cache — across sequence-length
//! buckets and LP Δ.
//!
//!     cargo run --release --bin fig7_walltime [-- --model td-small \
//!         --tokens-per-sec --reps 3 --no-simnet]
//!
//! Output: results/fig7_<model>.csv
//!   (task, seqlen, delta, eff_depth, wall_ms, tokens_per_s, speedup_vs_d0)

use std::time::Instant;

use truedepth::cli::Args;
use truedepth::harness::{default_net, no_net, write_csv, ScoringCtx};
use truedepth::model::{transform, ServingModel, Weights};
use truedepth::tensor::argmax;

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&["tokens-per-sec", "no-simnet"]);
    let model = args.get_or("model", "td-small");
    let reps = args.get_usize("reps", 3);
    let net = if args.flag("no-simnet") { no_net() } else { default_net() };

    let ctx = ScoringCtx::load(model)?;
    let entry = ctx.entry();
    let cfg = entry.config.clone();
    let n = cfg.n_layers;
    let weights = ctx.weights().unwrap_or_else(|_| Weights::random(&cfg, 9));
    let end = n - 2;

    // Δ sweep: 0 (baseline TP) then increasing LP coverage.
    let mut deltas = vec![0usize];
    let mut d = 4;
    while n >= d / 2 + 4 && d <= end {
        deltas.push(d);
        d += 4;
    }

    let seqlens = [32usize, 128, 224];
    let mut rows = Vec::new();
    let mut baseline_ms: std::collections::HashMap<(String, usize), f64> =
        std::collections::HashMap::new();

    for &delta in &deltas {
        let plan = if delta == 0 {
            transform::sequential(n)
        } else {
            let depth = n - delta / 2;
            match transform::lp_for_depth(n, depth, end) {
                Some(p) => p,
                None => continue,
            }
        };
        let depth = plan.effective_depth();
        let serving = ServingModel::new(&ctx.manifest, model, &weights, &plan, net.clone())?;
        let s = cfg.slots;
        println!("== Δ={delta} (effective depth {depth}) ==");

        for &t in &seqlens {
            let prompt: Vec<i32> = (0..t as i32).map(|i| 97 + (i % 26)).collect();

            // -- task 1: prefill
            let mut best = f64::MAX;
            for _ in 0..reps {
                let t0 = Instant::now();
                let _ = serving.prefill(0, &prompt)?;
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
            }
            push_row(&mut rows, &mut baseline_ms, "prefill", t, delta, depth, best, t as f64);

            // -- task 2: autoregressive generation of t/4 tokens
            let gen_n = (t / 4).max(8);
            let mut best = f64::MAX;
            for _ in 0..reps.min(2) {
                let logits = serving.prefill(0, &prompt[..8])?;
                let mut next = argmax(&logits) as i32;
                let mut pos = 8usize;
                let t0 = Instant::now();
                for _ in 0..gen_n {
                    let mut tok = vec![0i32; s];
                    let mut ps = vec![0i32; s];
                    tok[0] = next;
                    ps[0] = pos as i32;
                    let out = serving.decode_step(&tok, &ps)?;
                    next = argmax(&out[..cfg.vocab]) as i32;
                    pos += 1;
                }
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
            }
            push_row(&mut rows, &mut baseline_ms, "autoregen", t, delta, depth, best, gen_n as f64);

            // -- task 3: single-token decode with a prefilled cache of t
            let _ = serving.prefill(0, &prompt)?;
            let mut best = f64::MAX;
            for _ in 0..reps {
                let mut tok = vec![0i32; s];
                let mut ps = vec![0i32; s];
                tok[0] = 65;
                ps[0] = t as i32;
                let t0 = Instant::now();
                let _ = serving.decode_step(&tok, &ps)?;
                best = best.min(t0.elapsed().as_secs_f64() * 1e3);
            }
            push_row(&mut rows, &mut baseline_ms, "one_token", t, delta, depth, best, 1.0);
        }
    }

    write_csv(
        &format!("fig7_{model}.csv"),
        "task,seqlen,delta,eff_depth,wall_ms,tokens_per_s,speedup_vs_d0",
        &rows,
    );
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn push_row(
    rows: &mut Vec<String>,
    baseline: &mut std::collections::HashMap<(String, usize), f64>,
    task: &str,
    seqlen: usize,
    delta: usize,
    depth: usize,
    wall_ms: f64,
    tokens: f64,
) {
    let key = (task.to_string(), seqlen);
    if delta == 0 {
        baseline.insert(key.clone(), wall_ms);
    }
    let speedup = baseline.get(&key).map(|b| b / wall_ms).unwrap_or(1.0);
    let tps = tokens / (wall_ms / 1e3);
    println!(
        "  {task:<10} T={seqlen:<4} {wall_ms:>9.2} ms  {tps:>9.1} tok/s  speedup ×{speedup:.3}"
    );
    rows.push(format!(
        "{task},{seqlen},{delta},{depth},{wall_ms:.3},{tps:.2},{speedup:.4}"
    ));
}
