//! Fig. 3 regenerator: perplexity heatmaps for every contiguous-window
//! transformation — (a) shuffle, (b) prune, (c) merge, (d) parallel,
//! (e) contiguous 2-parallel. `--triplet` adds the §3 triplet ablation.
//!
//!     cargo run --release --bin fig3_heatmaps [-- --model td-small \
//!         --windows 2 --bucket 128 --triplet --fast]
//!
//! Output: results/fig3_<transform>_<model>.csv matrices (rows s, cols e;
//! empty cells for e <= s+1) plus a console summary of the paper's headline
//! observations (middle-window tolerance, prune≈merge, 2-parallel widest).

use truedepth::cli::Args;
use truedepth::eval::ppl::{eval_windows, perplexity};
use truedepth::harness::{write_csv, ScoringCtx};
use truedepth::model::{transform, Scorer};
use truedepth::text::corpus::DATA_SEED;
use truedepth::util::rng::SplitMix64;

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&["triplet", "fast"]);
    let model = args.get_or("model", "td-small");
    let bucket = args.get_usize("bucket", 128);
    let n_windows = args.get_usize("windows", 2);

    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let scorer = Scorer::new(&ctx.engine, entry, &weights, bucket)?;
    let windows = eval_windows(bucket, n_windows, DATA_SEED);

    let base = perplexity(&scorer, &transform::sequential(n), &windows)?;
    println!("model {model}: base ppl {base:.3} over {n_windows}×{bucket} tokens");

    type Builder = Box<dyn Fn(usize, usize) -> truedepth::model::GraphPlan>;
    let mut transforms: Vec<(&str, Builder)> = vec![
        (
            "shuffle",
            Box::new(move |s, e| {
                let mut rng = SplitMix64::new(DATA_SEED ^ (s * 64 + e) as u64);
                transform::shuffle(n, s, e, &mut rng)
            }),
        ),
        ("prune", Box::new(move |s, e| transform::prune(n, s, e))),
        ("merge", Box::new(move |s, e| transform::merge(n, s, e))),
        ("parallel", Box::new(move |s, e| transform::parallel(n, s, e))),
        ("pair2", Box::new(move |s, e| transform::pair_parallel(n, s, e, true))),
    ];
    if args.flag("triplet") {
        transforms.push(("triplet", Box::new(move |s, e| transform::triplet_parallel(n, s, e))));
    }

    let stride = if args.flag("fast") { 2 } else { 1 };
    let mut summary: Vec<(String, usize)> = Vec::new();
    for (name, build) in &transforms {
        let mut rows = Vec::new();
        let mut widest = 0usize;
        let mut widest_span = (0, 0);
        for s in (0..n).step_by(stride) {
            let mut cells = vec![format!("{s}")];
            for e in 1..=n {
                if e <= s + 1 || (e - s) % stride != 0 {
                    cells.push(String::new());
                    continue;
                }
                let plan = build(s, e);
                let ppl = perplexity(&scorer, &plan, &windows)?;
                cells.push(format!("{ppl:.3}"));
                let width = e - s;
                if ppl < 2.0 * base && width > widest {
                    widest = width;
                    widest_span = (s, e);
                }
            }
            rows.push(cells.join(","));
        }
        let header: Vec<String> =
            std::iter::once("s\\e".to_string()).chain((1..=n).map(|e| e.to_string())).collect();
        write_csv(&format!("fig3_{name}_{model}.csv"), &header.join(","), &rows);
        println!("{name:>9}: widest window with ppl < 2×base = {widest} layers {widest_span:?}");
        summary.push((name.to_string(), widest));
    }

    // paper-shape checks (console, non-fatal): 2-parallel tolerates the
    // widest windows; prune/merge are the most damaging.
    let get = |k: &str| summary.iter().find(|(n, _)| n == k).map(|(_, w)| *w).unwrap_or(0);
    println!("\nshape check:");
    println!(
        "  pair2 ({}) >= parallel ({}) >= prune ({}): {}",
        get("pair2"),
        get("parallel"),
        get("prune"),
        get("pair2") >= get("parallel") && get("parallel") >= get("prune")
    );
    println!("  merge ({}) vs prune ({}) (paper: near-identical)", get("merge"), get("prune"));
    Ok(())
}
