//! Deterministic perf-regression gate (the CI `perf-gate` job).
//!
//!     cargo run --release --bin perf_gate [-- --baseline rust/bench-baseline.json \
//!         --reports target/bench-reports --tolerance 0.02] [--write-baseline] \
//!         [--allow-regress]
//!
//! Compares the **deterministic** `"metrics"` objects of the bench JSON
//! reports (`Bench::metric` — modelled tokens/sec, modelled TTFT,
//! flops/token, α–β payload bytes; never wall-clock samples) against the
//! checked-in `rust/bench-baseline.json` and exits non-zero when any
//! metric regresses by more than the tolerance (default 2%). Because every
//! gated figure derives from the cost model and shape formulas rather than
//! machine speed, the gate is bit-stable across hosts: a failure means a
//! PR actually changed the modelled cost of the serving protocol.
//!
//! `MetricsSnapshot` files (`truedepth.metrics/v1`, written next to the
//! bench reports by the benches' observability export — see
//! `src/obs/snapshot.rs`) are read too: their flattened numeric leaves
//! join the metric map, and where a key collides with a scraped bench
//! metric the snapshot value wins, since the snapshot is the structured
//! source the report line was printed from. Chrome trace files in the same
//! directory have no `group`/schema key and are skipped.
//!
//! Re-baselining an intentional change: run with `--write-baseline` and
//! commit the refreshed file, including `[perf-baseline]` in the commit
//! message — CI passes `--allow-regress` for such commits so the gate
//! reports the diff without failing the run.
//!
//! Baseline schema: `{"tolerance": 0.02, "metrics": {"<group>.<name>":
//! {"value": f64, "better": "higher"|"lower"}}}`. Direction is stored per
//! metric (inferred from the name at `--write-baseline` time: throughput
//! names containing `per_s` are higher-is-better, everything else —
//! latency, flops, bytes, chunk counts — lower-is-better).
//!
//! A baseline entry with `"value": null` is a **bootstrap** entry: the
//! metric must be present in the reports (its absence fails the gate,
//! so the producing bench/loadtest run cannot silently drop out of CI),
//! but no numeric comparison happens yet — the gate prints the observed
//! value so it can be pinned (hand-edit or `--write-baseline`). This is
//! how metrics whose value can only be observed from a full run (e.g.
//! the cluster loadtest percentiles) enter the baseline.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use truedepth::cli::Args;
use truedepth::obs::MetricsSnapshot;
use truedepth::util::json::{num, obj, s, Value};

fn fail(msg: &str) -> ! {
    eprintln!("perf_gate: {msg}");
    std::process::exit(1);
}

/// Read every `<dir>/*.json` bench report into `group.name -> value`,
/// skipping the unit tests' `selftest*` scratch groups. `MetricsSnapshot`
/// documents flatten to `source.section.path` keys and are merged second,
/// so on a key collision the structured snapshot wins over the scrape.
fn collect_metrics(dir: &Path) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    let mut snapshots = BTreeMap::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => fail(&format!("cannot read reports dir {}: {e}", dir.display())),
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        let Ok(v) = Value::parse(&text) else {
            eprintln!("perf_gate: skipping unparsable {}", path.display());
            continue;
        };
        if MetricsSnapshot::is_snapshot_json(&v) {
            snapshots.extend(MetricsSnapshot::flatten(&v));
            continue;
        }
        let group = v.get("group").and_then(|g| g.as_str()).unwrap_or("").to_string();
        if group.is_empty() || group.starts_with("selftest") {
            continue;
        }
        if let Some(metrics) = v.get("metrics").and_then(|m| m.as_obj()) {
            for (name, val) in metrics {
                if let Some(x) = val.as_f64() {
                    out.insert(format!("{group}.{name}"), x);
                }
            }
        }
    }
    out.extend(snapshots);
    out
}

fn infer_direction(name: &str) -> &'static str {
    if name.contains("per_s") {
        "higher"
    } else {
        "lower"
    }
}

fn write_baseline(path: &Path, current: &BTreeMap<String, f64>, tolerance: f64) {
    let metrics = obj(
        current
            .iter()
            .map(|(k, &v)| {
                (
                    k.as_str(),
                    obj(vec![("value", num(v)), ("better", s(infer_direction(k)))]),
                )
            })
            .collect(),
    );
    let doc = obj(vec![("tolerance", num(tolerance)), ("metrics", metrics)]);
    if let Err(e) = std::fs::write(path, doc.to_string_pretty() + "\n") {
        fail(&format!("cannot write baseline {}: {e}", path.display()));
    }
    println!(
        "perf_gate: wrote baseline with {} metrics to {}",
        current.len(),
        path.display()
    );
}

fn main() {
    let args = Args::from_env(&["write-baseline", "allow-regress"]);
    let root = truedepth::repo_root();
    let baseline_path = match args.get_or("baseline", "") {
        "" => {
            // repo_root() is the workspace root under TRUEDEPTH_ROOT (CI),
            // but resolves to rust/ itself when invoked from inside the
            // crate — the baseline lives next to Cargo.toml either way.
            let from_workspace = root.join("rust/bench-baseline.json");
            if from_workspace.parent().is_some_and(|p| p.is_dir()) {
                from_workspace
            } else {
                root.join("bench-baseline.json")
            }
        }
        p => PathBuf::from(p),
    };
    let reports_dir = match args.get_or("reports", "") {
        "" => root.join("target/bench-reports"),
        p => PathBuf::from(p),
    };
    let current = collect_metrics(&reports_dir);
    if current.is_empty() {
        fail(&format!(
            "no deterministic metrics found under {} — run `cargo bench --bench \
             bench_decode --bench bench_prefill` first",
            reports_dir.display()
        ));
    }

    let cli_tol: Option<f64> = args
        .get("tolerance")
        .map(|t| t.parse().unwrap_or_else(|_| fail("bad --tolerance")));

    if args.flag("write-baseline") {
        write_baseline(&baseline_path, &current, cli_tol.unwrap_or(0.02));
        return;
    }

    let text = match std::fs::read_to_string(&baseline_path) {
        Ok(t) => t,
        Err(e) => fail(&format!(
            "cannot read baseline {} ({e}) — generate one with --write-baseline",
            baseline_path.display()
        )),
    };
    let doc =
        Value::parse(&text).unwrap_or_else(|e| fail(&format!("bad baseline json: {e}")));
    let tolerance = cli_tol
        .or_else(|| doc.get("tolerance").and_then(|t| t.as_f64()))
        .unwrap_or(0.02);
    let Some(base_metrics) = doc.get("metrics").and_then(|m| m.as_obj()) else {
        fail("baseline has no `metrics` object");
    };

    let mut failures = Vec::new();
    let mut improvements = 0usize;
    let mut checked = 0usize;
    for (name, entry) in base_metrics {
        let base = match entry.get("value") {
            Some(Value::Null) => {
                // bootstrap entry: presence-gated only, value not yet pinned
                match current.get(name) {
                    Some(&cur) => {
                        checked += 1;
                        println!(
                            "perf_gate: bootstrap metric `{name}` = {cur:.4} — pin \
                             this value in the baseline to arm the numeric gate"
                        );
                    }
                    None => failures.push(format!(
                        "{name}: missing from the bench reports (bootstrap entry — \
                         the producing run must still emit it)"
                    )),
                }
                continue;
            }
            v => v
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| fail(&format!("baseline metric `{name}` has no value"))),
        };
        let better = entry.get("better").and_then(|b| b.as_str()).unwrap_or("lower");
        let Some(&cur) = current.get(name) else {
            failures.push(format!(
                "{name}: missing from the bench reports (baseline {base:.4})"
            ));
            continue;
        };
        checked += 1;
        // relative change in the "worse" direction
        let rel = if base == 0.0 {
            if cur == 0.0 {
                0.0
            } else if better == "higher" {
                -1.0 // anything above a zero floor is an improvement
            } else {
                f64::INFINITY
            }
        } else {
            match better {
                "higher" => (base - cur) / base,
                _ => (cur - base) / base,
            }
        };
        if rel > tolerance {
            failures.push(format!(
                "{name}: {cur:.4} vs baseline {base:.4} ({:+.2}% in the worse \
                 direction, tolerance {:.1}%)",
                rel * 100.0,
                tolerance * 100.0
            ));
        } else if rel < -tolerance {
            improvements += 1;
            println!(
                "perf_gate: {name} improved: {cur:.4} vs baseline {base:.4} \
                 (consider re-baselining with --write-baseline + [perf-baseline])"
            );
        }
    }
    for name in current.keys() {
        if !base_metrics.contains_key(name) {
            println!(
                "perf_gate: note: new metric `{name}` not in the baseline \
                 (re-baseline to start gating it)"
            );
        }
    }

    println!(
        "perf_gate: {checked} metrics checked against {} (tolerance {:.1}%), \
         {improvements} improved, {} regressed",
        baseline_path.display(),
        tolerance * 100.0,
        failures.len()
    );
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("perf_gate: REGRESSION {f}");
        }
        if args.flag("allow-regress") {
            println!(
                "perf_gate: --allow-regress set ([perf-baseline] override) — \
                 reporting without failing"
            );
        } else {
            std::process::exit(1);
        }
    }
}
