//! Fig. 6 regenerator: perplexity when running Δ consecutive layers in
//! 2-parallel, as a function of the window END index — one series per Δ.
//! Also hosts the abl3 ablation (`--mode both`): deployed LP-TP numerics vs
//! the paper's PAR approximation (eq. 2).
//!
//!     cargo run --release --bin fig6_ppl_sweep [-- --model td-small \
//!         --windows 2 --bucket 128 --mode lp|par|both]
//!
//! Output: results/fig6_<model>[_par].csv with columns end_index, delta, ppl.

use truedepth::cli::Args;
use truedepth::eval::ppl::{eval_windows, perplexity};
use truedepth::harness::{write_csv, ScoringCtx};
use truedepth::model::{transform, Scorer};
use truedepth::text::corpus::DATA_SEED;

fn main() -> truedepth::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "td-small");
    let bucket = args.get_usize("bucket", 128);
    let n_windows = args.get_usize("windows", 2);
    let mode = args.get_or("mode", "lp");

    let ctx = ScoringCtx::load(model)?;
    let weights = ctx.weights()?;
    let entry = ctx.entry();
    let n = entry.config.n_layers;
    let scorer = Scorer::new(&ctx.engine, entry, &weights, bucket)?;
    let windows = eval_windows(bucket, n_windows, DATA_SEED);
    let base = perplexity(&scorer, &transform::sequential(n), &windows)?;
    println!("model {model}: base ppl {base:.3}");

    for (suffix, lp_numerics) in match mode {
        "lp" => vec![("", true)],
        "par" => vec![("_par", false)],
        "both" => vec![("", true), ("_par", false)],
        other => return Err(truedepth::Error::msg(format!("bad --mode {other}"))),
    } {
        let mut rows = Vec::new();
        let mut best: Option<(f64, usize)> = None;
        println!("\n== {} numerics ==", if lp_numerics { "LP-TP (deployed)" } else { "PAR (eq. 2)" });
        for delta in (2..n).step_by(2) {
            for end in delta..=n {
                let s = end - delta;
                let plan = transform::pair_parallel(n, s, end, lp_numerics);
                let ppl = perplexity(&scorer, &plan, &windows)?;
                rows.push(format!("{end},{delta},{ppl:.4}"));
                if delta == 6 {
                    // track the common optimal end index at a fixed Δ
                    match best {
                        Some((b, _)) if b <= ppl => {}
                        _ => best = Some((ppl, end)),
                    }
                }
            }
        }
        write_csv(
            &format!("fig6_{model}{suffix}.csv"),
            "end_index,delta,ppl",
            &rows,
        );
        if let Some((ppl, end)) = best {
            println!("Δ=6 optimal end index: {end} (ppl {ppl:.3}) — paper finds a common optimum near n-2");
        }
    }
    Ok(())
}
