//! TOML-subset parser: sections, key = value (string/int/float/bool),
//! `#` comments. Enough for run configs; deliberately strict elsewhere.

use crate::error::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn f64(&self) -> Result<f64> {
        match self {
            TomlValue::Num(n) => Ok(*n),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }
}

/// Parsed document: ordered (section, key, value) triples. Top-level keys
/// use section "".
#[derive(Debug, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, TomlValue)>,
}

impl TomlDoc {
    pub fn entries(&self) -> impl Iterator<Item = &(String, String, TomlValue)> {
        self.entries.iter()
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(body) = line.strip_prefix('[') {
            let name = body
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {}: unclosed section", lineno + 1)))?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("line {}: expected key = value", lineno + 1)))?;
        let key = k.trim().to_string();
        if key.is_empty() {
            return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
        }
        let value = parse_value(v.trim())
            .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
        doc.entries.push((section.clone(), key, value));
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> std::result::Result<TomlValue, String> {
    if let Some(body) = v.strip_prefix('"') {
        let inner = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    v.parse::<f64>()
        .map(TomlValue::Num)
        .map_err(|_| format!("cannot parse value `{v}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            "a = 1\n[s]\nb = \"x # not a comment\" # real comment\nc = true\nd = -2.5\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a"), Some(&TomlValue::Num(1.0)));
        assert_eq!(doc.get("s", "b"), Some(&TomlValue::Str("x # not a comment".into())));
        assert_eq!(doc.get("s", "c"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("s", "d"), Some(&TomlValue::Num(-2.5)));
    }

    #[test]
    fn errors_are_located() {
        let e = parse_toml("good = 1\nbad line\n").unwrap_err();
        assert!(e.to_string().contains("line 2"));
    }

    #[test]
    fn empty_and_comment_only() {
        let doc = parse_toml("# nothing\n\n   \n").unwrap();
        assert_eq!(doc.entries().count(), 0);
    }
}
