//! Configuration system: a TOML-subset parser plus the typed run config.
//!
//! Substrate note: the offline vendor set has no `serde`/`toml`, so this is
//! a hand-rolled parser covering the subset we use: `[section]` headers,
//! `key = value` with string / integer / float / bool values, `#` comments.

mod parse;

pub use parse::{parse_toml, TomlDoc};

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Interconnect cost model parameters (see `parallel::simnet`).
#[derive(Clone, Debug, PartialEq)]
pub struct InterconnectConfig {
    /// Per-collective base latency (software + link latency), seconds.
    pub alpha_s: f64,
    /// Link bandwidth, bytes/second.
    pub beta_bytes_per_s: f64,
    /// Set false to disable simulated cost entirely (raw host threads).
    pub enabled: bool,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        // Calibrated (EXPERIMENTS.md §Calibration) so that sync:compute on
        // two TP decoder layers matches the paper's Table 3 ratio
        // (100.8 : 217 ≈ 0.46): measured TP compute ≈ 2.45 ms per 2-layer
        // decode step on this testbed → 4 all-reduces × 280 µs ≈ 0.46×.
        InterconnectConfig {
            alpha_s: 280e-6,
            beta_bytes_per_s: 25e9,
            enabled: true,
        }
    }
}

/// Serving/coordination parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Decode slots (continuous batching width; fixed by the AOT artifacts).
    pub slots: usize,
    /// Max requests waiting in the batcher before back-pressure kicks in.
    pub queue_depth: usize,
    /// Batcher window: max time to wait to fill a batch.
    pub batch_wait_ms: u64,
    /// Max new tokens per request unless the request overrides.
    pub max_new_tokens: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { slots: 4, queue_depth: 256, batch_wait_ms: 2, max_new_tokens: 64 }
    }
}

/// Top-level runtime configuration.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub artifacts_dir: Option<PathBuf>,
    pub checkpoints_dir: Option<PathBuf>,
    pub interconnect: InterconnectConfig,
    pub server: ServerConfig,
}

impl RunConfig {
    /// Load from a TOML file; unknown keys are rejected (typo safety).
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = parse_toml(text)?;
        let mut cfg = RunConfig::default();
        for (section, key, val) in doc.entries() {
            match (section.as_str(), key.as_str()) {
                ("", "artifacts_dir") => cfg.artifacts_dir = Some(val.str()?.into()),
                ("", "checkpoints_dir") => cfg.checkpoints_dir = Some(val.str()?.into()),
                ("interconnect", "alpha_us") => cfg.interconnect.alpha_s = val.f64()? * 1e-6,
                ("interconnect", "beta_gb_per_s") => {
                    cfg.interconnect.beta_bytes_per_s = val.f64()? * 1e9
                }
                ("interconnect", "enabled") => cfg.interconnect.enabled = val.bool()?,
                ("server", "slots") => cfg.server.slots = val.f64()? as usize,
                ("server", "queue_depth") => cfg.server.queue_depth = val.f64()? as usize,
                ("server", "batch_wait_ms") => cfg.server.batch_wait_ms = val.f64()? as u64,
                ("server", "max_new_tokens") => cfg.server.max_new_tokens = val.f64()? as usize,
                (s, k) => {
                    return Err(Error::Config(format!("unknown config key [{s}] {k}")));
                }
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::default();
        assert!(c.interconnect.enabled);
        assert_eq!(c.server.slots, 4);
    }

    #[test]
    fn parses_full_config() {
        let c = RunConfig::from_toml(
            r#"
            # paths
            artifacts_dir = "artifacts"
            checkpoints_dir = "checkpoints"

            [interconnect]
            alpha_us = 12.5
            beta_gb_per_s = 50.0
            enabled = true

            [server]
            slots = 4
            queue_depth = 32
            batch_wait_ms = 5
            max_new_tokens = 16
            "#,
        )
        .unwrap();
        assert_eq!(c.artifacts_dir.as_deref(), Some(Path::new("artifacts")));
        assert!((c.interconnect.alpha_s - 12.5e-6).abs() < 1e-12);
        assert!((c.interconnect.beta_bytes_per_s - 50e9).abs() < 1.0);
        assert_eq!(c.server.queue_depth, 32);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(RunConfig::from_toml("wat = 3").is_err());
        assert!(RunConfig::from_toml("[interconnect]\nbogus = 1").is_err());
    }
}
