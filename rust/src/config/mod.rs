//! Configuration system: a TOML-subset parser plus the typed run config.
//!
//! Substrate note: the offline vendor set has no `serde`/`toml`, so this is
//! a hand-rolled parser covering the subset we use: `[section]` headers,
//! `key = value` with string / integer / float / bool values, `#` comments.

mod parse;

pub use parse::{parse_toml, TomlDoc};

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Interconnect cost model parameters (see `parallel::simnet`).
#[derive(Clone, Debug, PartialEq)]
pub struct InterconnectConfig {
    /// Per-collective base latency (software + link latency), seconds.
    pub alpha_s: f64,
    /// Link bandwidth, bytes/second.
    pub beta_bytes_per_s: f64,
    /// Set false to disable simulated cost entirely (raw host threads).
    pub enabled: bool,
}

impl Default for InterconnectConfig {
    fn default() -> Self {
        // Calibrated (EXPERIMENTS.md §Calibration) so that sync:compute on
        // two TP decoder layers matches the paper's Table 3 ratio
        // (100.8 : 217 ≈ 0.46): measured TP compute ≈ 2.45 ms per 2-layer
        // decode step on this testbed → 4 all-reduces × 280 µs ≈ 0.46×.
        InterconnectConfig {
            alpha_s: 280e-6,
            beta_bytes_per_s: 25e9,
            enabled: true,
        }
    }
}

/// Per-device compute/memory profile of the cost model (see
/// `parallel::simnet::CostModel` for the equations it parameterizes).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Peak arithmetic throughput, flops/second (roofline flop term).
    pub peak_flops_per_s: f64,
    /// Device-memory bandwidth, bytes/second (roofline memory term).
    pub hbm_bytes_per_s: f64,
    /// Fixed kernel launch/driver overhead per executable dispatch, seconds.
    pub launch_s: f64,
    /// Host↔device link bandwidth, bytes/second (PCIe-like; prices the
    /// traffic `MeshMetrics::host_transfers` meters).
    pub host_bytes_per_s: f64,
}

impl Default for DeviceProfile {
    fn default() -> Self {
        // Testbed calibration, same yardstick as the α–β defaults above:
        // the simulated accelerators are CPU-backed PJRT devices, so peak
        // is set so the modelled 2-layer TP decode compute (~2.4 ms for
        // td-small's ~3.9 Mflop round) matches the measured testbed compute
        // in EXPERIMENTS.md — keeping modelled sync:compute at the paper's
        // Table 3 ratio (≈0.46). GPU-scale profiles (A100-like) are built
        // explicitly where needed, e.g. `bin/fig7_modelled.rs`.
        DeviceProfile {
            peak_flops_per_s: 1.7e9,
            hbm_bytes_per_s: 10e9,
            launch_s: 20e-6,
            host_bytes_per_s: 5e9,
        }
    }
}

/// Runtime executable-cache parameters (see `runtime::buckets::ExecCache`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RuntimeConfig {
    /// Cap on compiled executables cached per serving model; beyond it the
    /// least-recently-used ones are evicted (and transparently recompiled
    /// on next use — evictions are visible as a `ServerMetrics` gauge).
    /// Config key `[runtime] max_cached_execs`; 0 or absent = unbounded.
    /// Consumed by `truedepth serve --config <file>` (CLI
    /// `--max-cached-execs` overrides) — programmatic builds apply it via
    /// `ServingModel::set_exec_cache_cap`.
    pub max_cached_execs: Option<usize>,
}

/// Serving/coordination parameters.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Decode slots (continuous batching width; fixed by the AOT artifacts).
    pub slots: usize,
    /// Max requests waiting in the batcher before back-pressure kicks in.
    pub queue_depth: usize,
    /// Batcher window: max time to wait to fill a batch.
    pub batch_wait_ms: u64,
    /// Max new tokens per request unless the request overrides.
    pub max_new_tokens: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { slots: 4, queue_depth: 256, batch_wait_ms: 2, max_new_tokens: 64 }
    }
}

/// Top-level runtime configuration.
#[derive(Clone, Debug, Default)]
pub struct RunConfig {
    pub artifacts_dir: Option<PathBuf>,
    pub checkpoints_dir: Option<PathBuf>,
    pub interconnect: InterconnectConfig,
    pub device: DeviceProfile,
    pub server: ServerConfig,
    pub runtime: RuntimeConfig,
}

impl RunConfig {
    /// Load from a TOML file; unknown keys are rejected (typo safety).
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// The cost model this config describes (`[interconnect]` + `[device]`)
    /// — hand it to `ServingModel::new_with_cost` / `Mesh::with_cost`.
    pub fn cost_model(&self) -> crate::parallel::CostModel {
        crate::parallel::CostModel::new(self.interconnect.clone(), self.device.clone())
    }

    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = parse_toml(text)?;
        let mut cfg = RunConfig::default();
        for (section, key, val) in doc.entries() {
            match (section.as_str(), key.as_str()) {
                ("", "artifacts_dir") => cfg.artifacts_dir = Some(val.str()?.into()),
                ("", "checkpoints_dir") => cfg.checkpoints_dir = Some(val.str()?.into()),
                ("interconnect", "alpha_us") => cfg.interconnect.alpha_s = val.f64()? * 1e-6,
                ("interconnect", "beta_gb_per_s") => {
                    cfg.interconnect.beta_bytes_per_s = val.f64()? * 1e9
                }
                ("interconnect", "enabled") => cfg.interconnect.enabled = val.bool()?,
                ("device", "peak_gflops") => cfg.device.peak_flops_per_s = val.f64()? * 1e9,
                ("device", "hbm_gb_per_s") => {
                    cfg.device.hbm_bytes_per_s = val.f64()? * 1e9
                }
                ("device", "launch_us") => cfg.device.launch_s = val.f64()? * 1e-6,
                ("device", "host_gb_per_s") => {
                    cfg.device.host_bytes_per_s = val.f64()? * 1e9
                }
                ("runtime", "max_cached_execs") => {
                    let v = val.f64()? as usize;
                    cfg.runtime.max_cached_execs = (v > 0).then_some(v);
                }
                ("server", "slots") => cfg.server.slots = val.f64()? as usize,
                ("server", "queue_depth") => cfg.server.queue_depth = val.f64()? as usize,
                ("server", "batch_wait_ms") => cfg.server.batch_wait_ms = val.f64()? as u64,
                ("server", "max_new_tokens") => cfg.server.max_new_tokens = val.f64()? as usize,
                (s, k) => {
                    return Err(Error::Config(format!("unknown config key [{s}] {k}")));
                }
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = RunConfig::default();
        assert!(c.interconnect.enabled);
        assert_eq!(c.server.slots, 4);
        assert!(c.device.peak_flops_per_s > 0.0);
        assert!(c.device.hbm_bytes_per_s > 0.0);
        assert!(c.device.launch_s >= 0.0);
        assert!(c.device.host_bytes_per_s > 0.0);
    }

    #[test]
    fn parses_full_config() {
        let c = RunConfig::from_toml(
            r#"
            # paths
            artifacts_dir = "artifacts"
            checkpoints_dir = "checkpoints"

            [interconnect]
            alpha_us = 12.5
            beta_gb_per_s = 50.0
            enabled = true

            [device]
            peak_gflops = 312000.0
            hbm_gb_per_s = 2000.0
            launch_us = 5.0
            host_gb_per_s = 25.0

            [runtime]
            max_cached_execs = 64

            [server]
            slots = 4
            queue_depth = 32
            batch_wait_ms = 5
            max_new_tokens = 16
            "#,
        )
        .unwrap();
        assert_eq!(c.artifacts_dir.as_deref(), Some(Path::new("artifacts")));
        assert!((c.interconnect.alpha_s - 12.5e-6).abs() < 1e-12);
        assert!((c.interconnect.beta_bytes_per_s - 50e9).abs() < 1.0);
        assert!((c.device.peak_flops_per_s - 312e12).abs() < 1.0);
        assert!((c.device.hbm_bytes_per_s - 2e12).abs() < 1.0);
        assert!((c.device.launch_s - 5e-6).abs() < 1e-12);
        assert!((c.device.host_bytes_per_s - 25e9).abs() < 1.0);
        assert_eq!(c.server.queue_depth, 32);
        assert_eq!(c.runtime.max_cached_execs, Some(64));
        // 0 (and absence) mean unbounded
        assert_eq!(
            RunConfig::from_toml("[runtime]\nmax_cached_execs = 0")
                .unwrap()
                .runtime
                .max_cached_execs,
            None
        );
        assert_eq!(RunConfig::default().runtime.max_cached_execs, None);
        // the parsed sections flow into a usable cost model
        let cm = c.cost_model();
        assert!((cm.net.cfg.alpha_s - 12.5e-6).abs() < 1e-12);
        assert!((cm.dev.peak_flops_per_s - 312e12).abs() < 1.0);
        assert!(cm.compute_cost(312_000_000, 0).as_nanos() > 0);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(RunConfig::from_toml("wat = 3").is_err());
        assert!(RunConfig::from_toml("[interconnect]\nbogus = 1").is_err());
        assert!(RunConfig::from_toml("[device]\nbogus = 1").is_err());
        assert!(RunConfig::from_toml("[runtime]\nbogus = 1").is_err());
    }
}
