//! Loopback end-to-end tests of the network serving edge: a real
//! `TcpListener` on 127.0.0.1:0, real sockets, hand-rolled HTTP/1.1 on
//! the client side so nothing but std is exercised on either end.
//!
//! The tentpole acceptance lives here: streamed SSE tokens must be
//! bit-identical to the in-process oracle on every manifest tier, an
//! over-capacity burst must shed with 429s and ZERO slot churn
//! (`slot_allocs` stays at the completion count), and a mid-stream client
//! disconnect must reclaim the slot while the scheduler keeps running.
//! No-ops gracefully when `make artifacts` hasn't run (same convention as
//! `tests/integration.rs`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use truedepth::api::{CompletionRequest, ModelInfo, ModelsResponse};
use truedepth::config::ServerConfig;
use truedepth::coordinator::{Server, TokenEvent};
use truedepth::harness::no_net;
use truedepth::model::{transform, ServingModel, Weights};
use truedepth::runtime::Manifest;
use truedepth::serve::{serve, HttpConfig, SingleBackend};
use truedepth::util::json::Value;

/// The `GET /v1/models` document a single-server edge advertises.
fn models_doc(model: &ServingModel) -> ModelsResponse {
    ModelsResponse {
        models: vec![ModelInfo {
            model: "td-small".into(),
            tiers: model.variant_ids().iter().map(|v| v.as_str().to_string()).collect(),
            default_tier: model.default_tier().to_string(),
        }],
        replicas: 1,
    }
}

// ---- tiny std-only HTTP client ---------------------------------------------

/// De-frame a chunked transfer body.
fn dechunk(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let pos = b.windows(2).position(|w| w == b"\r\n").expect("chunk size line");
        let n = usize::from_str_radix(std::str::from_utf8(&b[..pos]).unwrap().trim(), 16)
            .expect("hex chunk size");
        b = &b[pos + 2..];
        if n == 0 {
            break;
        }
        out.extend_from_slice(&b[..n]);
        b = &b[n + 2..]; // skip the chunk's trailing CRLF
    }
    out
}

/// Split a raw response into (status, body), de-chunking when needed.
fn parse_response(raw: &[u8]) -> (u16, String) {
    let head_end =
        raw.windows(4).position(|w| w == b"\r\n\r\n").expect("head/body split") + 4;
    let head = String::from_utf8_lossy(&raw[..head_end]).into_owned();
    let status: u16 =
        head.split(' ').nth(1).expect("status code").parse().expect("numeric status");
    let body = if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        dechunk(&raw[head_end..])
    } else {
        raw[head_end..].to_vec()
    };
    (status, String::from_utf8_lossy(&body).into_owned())
}

/// One full request/response exchange over a fresh connection.
fn send(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(raw.as_bytes()).expect("send request");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read response");
    parse_response(&buf)
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    send(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    send(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// The `data:` payloads of an SSE body, in order.
fn sse_payloads(body: &str) -> Vec<String> {
    body.split("\n\n")
        .filter(|s| !s.is_empty())
        .map(|s| s.strip_prefix("data: ").expect("sse data prefix").to_string())
        .collect()
}

// ---- server bring-up (artifact-gated) --------------------------------------

/// Single-plan server (LP pair plan) behind an edge on 127.0.0.1:0.
fn boot(queue_depth: usize) -> Option<(Arc<Server>, truedepth::serve::HttpHandle)> {
    let manifest = Manifest::load_default().ok()?;
    let cfg = manifest.model("td-small").ok()?.config.clone();
    let weights = Weights::random(&cfg, 11);
    let plan = transform::pair_parallel(cfg.n_layers, 2, 10, true);
    let model = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).ok()?;
    let models = models_doc(&model);
    let server = Arc::new(Server::start(
        model,
        &ServerConfig { queue_depth, ..Default::default() },
    ));
    let edge = serve(
        Arc::new(SingleBackend::new(server.clone(), models)),
        "127.0.0.1:0",
        &HttpConfig { workers: 8, backlog: 32 },
    )
    .expect("bind loopback edge");
    Some((server, edge))
}

/// Multi-tier server over the manifest's plan-variant registry.
fn boot_multi() -> Option<(Arc<Server>, truedepth::serve::HttpHandle, Vec<String>)> {
    let manifest = Manifest::load_default().ok()?;
    let cfg = manifest.model("td-small").ok()?.config.clone();
    let weights = Weights::random(&cfg, 11);
    let model = ServingModel::from_manifest(&manifest, "td-small", &weights, no_net()).ok()?;
    let tiers: Vec<String> = model.variant_ids().iter().map(|v| v.as_str().to_string()).collect();
    if tiers.len() < 3 {
        return None; // legacy artifacts without the variants section
    }
    let models = models_doc(&model);
    let server = Arc::new(Server::start(
        model,
        &ServerConfig { queue_depth: 16, ..Default::default() },
    ));
    let edge = serve(
        Arc::new(SingleBackend::new(server.clone(), models)),
        "127.0.0.1:0",
        &HttpConfig { workers: 8, backlog: 32 },
    )
    .expect("bind loopback edge");
    Some((server, edge, tiers))
}

const WAIT: Duration = Duration::from_secs(120);

/// A prompt whose greedy decode runs the FULL 200-token budget on this
/// server's (random-but-seeded) weights — i.e. never samples EOS. The
/// load-shed and disconnect tests need requests that stay in flight on
/// demand; probing in-process keeps that deterministic instead of hoping
/// a hardcoded prompt never cycles through EOS. Returns `None` (skip)
/// in the unlikely case every candidate stops early.
fn long_prompt(server: &Server) -> Option<String> {
    for p in ["the red fox", "9 - 4 = ", "the calm ship", "a b c d e"] {
        let h = server.request(CompletionRequest::new(p).max_tokens(200)).unwrap();
        let r = h.wait_timeout(WAIT).unwrap();
        if r.error.is_none() && r.tokens.len() == 200 {
            return Some(p.to_string());
        }
    }
    eprintln!("http_serve: every probe prompt hit EOS early — skipping");
    None
}

// ---- the tests -------------------------------------------------------------

/// Tentpole acceptance, oracle half: concurrent streamed requests on
/// every manifest tier over real sockets; the SSE token chunks AND the
/// final response must be bit-identical to the in-process oracle
/// (deterministic greedy decode makes the oracle exact, not statistical).
#[test]
fn streamed_tokens_match_in_process_oracle_across_tiers() {
    let Some((server, edge, tiers)) = boot_multi() else { return };
    // oracle: the same (prompt, tier) pairs through the in-process path
    let mut oracle = Vec::new();
    for tier in &tiers {
        let req = CompletionRequest::new(format!("the red fox and {tier}"))
            .max_tokens(5)
            .tier(tier);
        let resp = server.request(req).unwrap().wait_timeout(WAIT).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        oracle.push(resp.tokens);
    }
    // the same requests, concurrently, over HTTP with "stream": true
    let addr = edge.local_addr();
    let threads: Vec<_> = tiers
        .iter()
        .cloned()
        .map(|tier| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt":"the red fox and {tier}","max_tokens":5,"tier":"{tier}","stream":true}}"#
                );
                post(addr, "/v1/completions", &body)
            })
        })
        .collect();
    for (i, t) in threads.into_iter().enumerate() {
        let (status, body) = t.join().unwrap();
        assert_eq!(status, 200, "tier {}: {body}", tiers[i]);
        let events = sse_payloads(&body);
        let n = oracle[i].len();
        assert_eq!(events.last().map(String::as_str), Some("[DONE]"), "{body}");
        assert_eq!(events.len(), n + 2, "{n} chunks + final response + [DONE]: {body}");
        // per-token chunks: contiguous indices, oracle-identical tokens
        let mut streamed = Vec::new();
        for (idx, ev) in events[..n].iter().enumerate() {
            let chunk = Value::parse(ev).expect("chunk json");
            assert_eq!(chunk.get("index").and_then(Value::as_usize), Some(idx), "{ev}");
            streamed.push(chunk.get("token").and_then(Value::as_f64).unwrap() as i32);
        }
        assert_eq!(streamed, oracle[i], "tier {}: streamed tokens diverge", tiers[i]);
        // the final response event repeats the full token list and tier
        let fin = Value::parse(&events[n]).expect("final response json");
        let tokens: Vec<i32> = fin
            .get("tokens")
            .and_then(Value::as_arr)
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as i32)
            .collect();
        assert_eq!(tokens, oracle[i]);
        assert_eq!(fin.get("tier").and_then(Value::as_str), Some(tiers[i].as_str()));
        assert_eq!(fin.get("completion_tokens").and_then(Value::as_usize), Some(n));
    }
    edge.shutdown();
}

/// Tentpole acceptance, load-shed half: with every KV slot occupied and
/// the submit queue full, an HTTP burst is rejected with 429 + the
/// `overloaded` envelope — and `slot_allocs` proves the rejected requests
/// never claimed (or churned) a slot.
#[test]
fn overload_burst_sheds_with_429_and_zero_slot_churn() {
    let Some((server, edge)) = boot(2) else { return };
    let Some(prompt) = long_prompt(&server) else { return };
    let addr = edge.local_addr();
    // the probe itself completed requests — assert deltas from here on
    let base_allocs = server.metrics.slot_allocs.load(Ordering::Relaxed);
    let base_done = server.metrics.requests_completed.load(Ordering::Relaxed);
    let slots = 4; // td-small serving config
    // occupy every slot with a long-running stream (the probed prompt is
    // guaranteed to decode all 200 tokens); submitting one at a time and
    // waiting for its first token keeps admission deterministic
    let mut occupiers = Vec::new();
    for _ in 0..slots {
        let h = server
            .request(CompletionRequest::new(prompt.as_str()).max_tokens(200))
            .unwrap();
        match h.next_event_timeout(WAIT) {
            Some(TokenEvent::Token { index: 0, .. }) => {}
            other => panic!("expected first token, got {other:?}"),
        }
        occupiers.push(h);
    }
    // slots full -> the scheduler stops draining -> the queue (depth 2)
    // accepts exactly two more and then back-pressures
    let queued: Vec<_> = (0..2)
        .map(|i| {
            server
                .request(CompletionRequest::new(format!("queued {i} the red fox")).max_tokens(2))
                .unwrap()
        })
        .collect();
    let overflow = match server.request(CompletionRequest::new("overflow")) {
        Err(e) => e,
        Ok(_) => panic!("7th request must hit queue back-pressure"),
    };
    assert!(overflow.to_string().contains("queue full (back-pressure)"), "{overflow}");
    // the HTTP burst: every request must shed with the 429 envelope
    for i in 0..5 {
        let (status, body) =
            post(addr, "/v1/completions", &format!(r#"{{"prompt":"burst {i}"}}"#));
        assert_eq!(status, 429, "burst {i}: {body}");
        assert!(body.contains(r#""code":"overloaded""#), "{body}");
        assert!(body.contains("queue full (back-pressure)"), "{body}");
    }
    // drain everything that was admitted
    for h in occupiers {
        let r = h.wait_timeout(WAIT).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    for h in queued {
        let r = h.wait_timeout(WAIT).unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    // zero slot churn: of everything since the probe, only the six
    // completions (4 occupiers + 2 queued) ever claimed a slot — none of
    // the six rejections (1 in-process + 5 HTTP) moved the counter — and
    // the live /metrics endpoint agrees with the in-process counters
    let allocs = server.metrics.slot_allocs.load(Ordering::Relaxed);
    assert_eq!(allocs, base_allocs + 6);
    assert_eq!(server.metrics.requests_completed.load(Ordering::Relaxed), base_done + 6);
    assert_eq!(server.metrics.requests_rejected.load(Ordering::Relaxed), 6);
    let (status, body) = get(addr, "/metrics");
    assert_eq!(status, 200);
    let flat = truedepth::obs::MetricsSnapshot::flatten(&Value::parse(&body).unwrap());
    assert_eq!(flat.get("serve.server.slot_allocs"), Some(&(allocs as f64)), "{body}");
    assert_eq!(flat.get("serve.server.requests_rejected"), Some(&6.0));
    edge.shutdown();
}

/// Protocol-level rejects and probes: each failure mode answers with its
/// taxonomy status + stable code, and the probe endpoints stay simple.
#[test]
fn protocol_errors_map_to_the_taxonomy() {
    let Some((server, edge)) = boot(8) else { return };
    let addr = edge.local_addr();
    let (status, body) = get(addr, "/healthz");
    assert_eq!((status, body.as_str()), (200, "ok"));
    // malformed JSON
    let (status, body) = post(addr, "/v1/completions", r#"{"prompt":"x""#);
    assert_eq!(status, 400, "{body}");
    assert!(body.contains(r#""code":"invalid_request""#), "{body}");
    // unknown + duplicate fields
    let (status, body) = post(addr, "/v1/completions", r#"{"prompt":"x","promt":"y"}"#);
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("unknown field `promt`"), "{body}");
    // missing body
    let (status, body) =
        send(addr, "POST /v1/completions HTTP/1.1\r\nHost: t\r\n\r\n");
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("missing request body"), "{body}");
    // oversized body: rejected from the Content-Length header alone (the
    // declared size is never transmitted, and the server never reads it)
    let (status, body) = send(
        addr,
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: 2000000\r\n\r\n",
    );
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("exceeds"), "{body}");
    // unknown tier: 404 with the stable code and the available tiers named
    let (status, body) =
        post(addr, "/v1/completions", r#"{"prompt":"x","tier":"turbo"}"#);
    assert_eq!(status, 404, "{body}");
    assert!(body.contains(r#""code":"unknown_tier""#), "{body}");
    assert!(body.contains("turbo"), "{body}");
    // unknown route
    let (status, body) = get(addr, "/v2/chat");
    assert_eq!(status, 404, "{body}");
    assert!(body.contains(r#""code":"not_found""#), "{body}");
    // none of the rejects touched a slot or the scheduler's reject path
    // beyond admission (tier reject counts as requests_rejected)
    assert_eq!(server.metrics.slot_allocs.load(Ordering::Relaxed), 0);
    edge.shutdown();
}

/// `GET /v1/models` advertises the served model, every manifest tier and
/// the replica count, matching the wire shape pinned in `docs/api.md`.
#[test]
fn models_route_lists_tiers_and_replica_count() {
    let Some((_server, edge, tiers)) = boot_multi() else { return };
    let (status, body) = get(edge.local_addr(), "/v1/models");
    assert_eq!(status, 200, "{body}");
    let doc = Value::parse(&body).expect("models json");
    assert_eq!(doc.get("replicas").and_then(Value::as_usize), Some(1), "{body}");
    let models = doc.get("models").and_then(Value::as_arr).expect("models array");
    assert_eq!(models.len(), 1, "{body}");
    let m = &models[0];
    assert_eq!(m.get("model").and_then(Value::as_str), Some("td-small"), "{body}");
    let listed: Vec<&str> = m
        .get("tiers")
        .and_then(Value::as_arr)
        .expect("tiers array")
        .iter()
        .map(|v| v.as_str().unwrap())
        .collect();
    assert_eq!(listed, tiers.iter().map(String::as_str).collect::<Vec<_>>(), "{body}");
    let default = m.get("default_tier").and_then(Value::as_str).expect("default tier");
    assert!(tiers.iter().any(|t| t == default), "{body}");
    edge.shutdown();
}

/// A client that hangs up mid-stream must cancel its request at the next
/// token boundary: slot reclaimed, `requests_cancelled` bumped, scheduler
/// still serving.
#[test]
fn mid_stream_disconnect_reclaims_the_slot() {
    let Some((server, edge)) = boot(8) else { return };
    let Some(prompt) = long_prompt(&server) else { return };
    let addr = edge.local_addr();
    let base_allocs = server.metrics.slot_allocs.load(Ordering::Relaxed);
    let base_done = server.metrics.requests_completed.load(Ordering::Relaxed);
    // start a long streamed completion and read only the first token (the
    // probed prompt guarantees 200 tokens were coming — the stream cannot
    // finish on its own out from under the disconnect)
    let mut s = TcpStream::connect(addr).unwrap();
    let body = format!(r#"{{"prompt":"{prompt}","max_tokens":200,"stream":true}}"#);
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut seen = Vec::new();
    let mut chunk = [0u8; 256];
    let deadline = Instant::now() + WAIT;
    while !seen.windows(6).any(|w| w == b"data: ") {
        assert!(Instant::now() < deadline, "no SSE data before deadline");
        let n = s.read(&mut chunk).expect("read stream");
        assert!(n > 0, "server closed the stream early");
        seen.extend_from_slice(&chunk[..n]);
    }
    drop(s); // hang up mid-stream
    // the scheduler notices at a token boundary: cancelled + reclaimed
    let deadline = Instant::now() + WAIT;
    while server.metrics.requests_cancelled.load(Ordering::Relaxed) == 0 {
        assert!(Instant::now() < deadline, "cancellation never observed");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(server.metrics.requests_cancelled.load(Ordering::Relaxed), 1);
    // the edge and scheduler keep serving after the disconnect (the same
    // prompt capped at 2 tokens: a prefix of the probed 200-token stream)
    let (status, body) = post(
        addr,
        "/v1/completions",
        &format!(r#"{{"prompt":"{prompt}","max_tokens":2}}"#),
    );
    assert_eq!(status, 200, "{body}");
    let fin = Value::parse(&body).unwrap();
    assert_eq!(fin.get("completion_tokens").and_then(Value::as_usize), Some(2));
    // both requests claimed exactly one slot each — the cancelled one's
    // slot went back to the pool, not into churn
    assert_eq!(server.metrics.slot_allocs.load(Ordering::Relaxed), base_allocs + 2);
    assert_eq!(server.metrics.requests_completed.load(Ordering::Relaxed), base_done + 1);
    edge.shutdown();
}
