//! Regression: a rank-divergent plan (one rank issuing a collective the
//! other never reaches) used to **hang** the mesh at serve time — the
//! blocked rank waits in its collective forever. The dynamic half of this
//! test reproduces that hang in miniature with rendezvous-style
//! collectives under a timeout; the static half shows `collective_check`
//! flags exactly the same stream pair at load time, turning the deadlock
//! into a diagnosable error before any request is admitted.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use truedepth::verify::{collective_check, CollectiveEvent, CollectiveKind};

fn reduce(name: &str, elems: usize) -> CollectiveEvent {
    CollectiveEvent { kind: CollectiveKind::Reduce, name: name.to_string(), elems }
}

/// Walk two ranks' collective streams concurrently. Each collective is a
/// rendezvous: a rank announces its event and blocks until the peer
/// announces one too (the NCCL model — a collective completes only when
/// every rank has entered it). Returns false if any rank was still
/// blocked in a collective when the timeout fired — the observed hang.
fn ranks_complete(streams: &[Vec<CollectiveEvent>; 2]) -> bool {
    let (tx0, rx1) = mpsc::channel::<String>();
    let (tx1, rx0) = mpsc::channel::<String>();
    let spawn = |events: Vec<CollectiveEvent>,
                 tx: mpsc::Sender<String>,
                 rx: mpsc::Receiver<String>| {
        thread::spawn(move || {
            for ev in events {
                tx.send(ev.to_string()).ok();
                if rx.recv_timeout(Duration::from_millis(250)).is_err() {
                    return false; // peer never rendezvoused: deadlock
                }
            }
            true
        })
    };
    let h0 = spawn(streams[0].clone(), tx0, rx0);
    let h1 = spawn(streams[1].clone(), tx1, rx1);
    h0.join().unwrap() & h1.join().unwrap()
}

#[test]
fn uniform_collective_streams_complete() {
    let stream = vec![reduce("act.partial", 32), reduce("act.partial", 32)];
    let streams = [stream.clone(), stream];
    assert!(ranks_complete(&streams), "uniform streams must not deadlock");
    let d = collective_check("m", &"lp".into(), "decode", &streams);
    assert!(d.is_empty(), "{d:?}");
}

#[test]
fn divergent_plan_hangs_dynamically_and_is_flagged_statically() {
    // rank 0 issues two all-reduces per step, rank 1 only one — the shape
    // a rank-divergent stage walk produces (e.g. ranks disagreeing on the
    // number of stages). Dynamically this deadlocks: rank 0 blocks in its
    // second collective while rank 1 has already exited the step.
    let streams = [
        vec![reduce("act.partial", 32), reduce("act.partial", 32)],
        vec![reduce("act.partial", 32)],
    ];
    assert!(!ranks_complete(&streams), "divergent streams must hang");

    // the same stream pair is a *load-time error* under the checker
    let d = collective_check("m", &"lp".into(), "decode", &streams);
    assert_eq!(d.len(), 1, "{d:?}");
    let msg = d[0].to_string();
    assert!(msg.contains("collective.count-diverged"), "{msg}");
    assert!(msg.contains("deadlock"), "{msg}");
    assert!(msg.contains("variant `lp`"), "diagnostic must name the tier: {msg}");
}

#[test]
fn payload_divergence_is_flagged_before_it_corrupts_a_reduce() {
    let streams = [vec![reduce("act.partial", 32)], vec![reduce("act.partial", 64)]];
    let d = collective_check("m", &"lp".into(), "decode", &streams);
    assert_eq!(d.len(), 1, "{d:?}");
    assert!(d[0].to_string().contains("collective.payload-diverged"), "{}", d[0]);
}
