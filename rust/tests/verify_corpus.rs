//! Malformed-manifest corpus: every diagnostic class the static verifier
//! (and the strict loader) can emit is exercised by a checked-in corpus
//! entry under `tests/corpus/<case>/manifest.json`, each asserting the
//! specific rejection it provokes. Regenerate with the generator snippet
//! in the PR that introduced them — the files are plain JSON, hand-edits
//! are fine too.

use std::path::{Path, PathBuf};

use truedepth::runtime::Manifest;
use truedepth::verify;

fn corpus(case: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus").join(case)
}

#[test]
fn wellformed_corpus_manifest_loads_and_verifies_clean() {
    let m = Manifest::load(&corpus("wellformed")).expect("wellformed must load");
    let report = verify::verify_manifest(&m);
    assert!(report.is_clean(), "{}", report.render());
}

/// Every malformed entry is rejected *at load time* with its specific
/// diagnostic; variant-scoped findings carry the `VariantId`.
#[test]
fn every_malformed_corpus_entry_fails_with_its_diagnostic() {
    // (case, expected substring of the load error, variant-qualified?)
    let cases = [
        ("layer_covered_twice", "plan.layer-covered-twice", true),
        ("layer_missing", "plan.layer-missing", true),
        ("layer_out_of_range", "plan.layer-out-of-range", true),
        ("pair_not_adjacent", "plan.pair-not-adjacent", true),
        ("missing_lp_executable", "lpattn_decode", true),
        ("missing_prefill_bucket", "seq bucket 64", true),
        // parser-level rejections (satellite of the verify pass: the
        // loader no longer silently accepts these)
        ("stage_arity", "malformed", true),
        ("duplicate_variant_id", "duplicate object key `lp`", false),
        ("empty_variants", "`variants` section is empty", false),
        ("duplicate_batch_bucket", "duplicate batch bucket 1", false),
        // model-level plan findings
        ("bucket_exceeds_slots", "plan.bucket-exceeds-slots", false),
        ("chunk_not_dividing_ctx", "plan.chunk-not-dividing-ctx", false),
        // paged-KV geometry findings (kv_pages section)
        ("page_not_dividing_chunk", "plan.page-not-dividing-chunk", false),
        ("page_pool_too_small", "plan.page-pool-too-small", false),
    ];
    for (case, want, qualified) in cases {
        let err = Manifest::load(&corpus(case))
            .err()
            .unwrap_or_else(|| panic!("{case}: must be rejected at load time"));
        let msg = err.to_string();
        assert!(msg.contains(want), "{case}: error must mention `{want}`:\n{msg}");
        if qualified {
            assert!(
                msg.contains("variant `"),
                "{case}: diagnostic must be variant-qualified:\n{msg}"
            );
        }
    }
}

/// Warning-class findings (degraded-but-servable manifests) pass the
/// normal load, surface in the report, and fail only the strict load.
#[test]
fn warning_class_corpus_entries_load_but_fail_strict() {
    let cases = [
        ("bucket_missing_executable", "plan.bucket-missing-executable"),
        ("band_gap", "plan.band-not-contiguous"),
    ];
    for (case, code) in cases {
        let dir = corpus(case);
        let m = Manifest::load(&dir)
            .unwrap_or_else(|e| panic!("{case}: warnings must not reject a load: {e}"));
        let report = verify::verify_manifest(&m);
        assert!(
            !report.is_clean() && !report.has_errors(),
            "{case}: want warnings only:\n{}",
            report.render()
        );
        assert!(report.render().contains(code), "{case}:\n{}", report.render());
        assert!(
            Manifest::load_strict(&dir).is_err(),
            "{case}: strict load must reject warnings"
        );
    }
    // the band-gap warning names the tier it applies to
    let report = verify::verify_manifest(&Manifest::load(&corpus("band_gap")).unwrap());
    assert!(report.render().contains("variant `lp`"), "{}", report.render());
}
