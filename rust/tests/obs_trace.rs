//! End-to-end observability: a traced `Server` run must export a valid
//! Chrome trace-event JSON (per-request spans with tier attributes plus
//! mesh collective events on the simulated clock) and a machine-readable
//! metrics snapshot — and both must be byte-identical across identical
//! runs, since every timestamp comes from the deterministic modelled
//! clock, never the wall. No-ops gracefully when `make artifacts` hasn't
//! run (same convention as `tests/integration.rs`).

use std::sync::Arc;

use truedepth::api::CompletionRequest;
use truedepth::config::ServerConfig;
use truedepth::coordinator::Server;
use truedepth::harness::default_net;
use truedepth::model::{ServingModel, Weights};
use truedepth::obs::{MetricsSnapshot, Tracer};
use truedepth::runtime::Manifest;
use truedepth::util::json::Value;

/// One traced serving run over the full plan-variant registry: three
/// requests cycling through the tiers, submitted blocking so the request
/// order (and with it the trace) is fully deterministic. Returns the
/// pretty-printed Chrome trace and metrics snapshot.
fn run_once() -> Option<(String, String)> {
    let manifest = Manifest::load_default().ok()?;
    let cfg = manifest.model("td-small").ok()?.config.clone();
    let weights = Weights::random(&cfg, 2026);
    let serving =
        ServingModel::from_manifest(&manifest, "td-small", &weights, default_net()).ok()?;
    let tiers: Vec<String> =
        serving.variant_ids().iter().map(|v| v.as_str().to_string()).collect();
    let tracer = Arc::new(Tracer::new());
    let server = Server::start_traced(serving, &ServerConfig::default(), tracer.clone());
    for (i, prompt) in ["the red fox", "9 - 4 = ", "the calm ship"].iter().enumerate() {
        let req = CompletionRequest::new(*prompt)
            .max_tokens(3)
            .tier(&tiers[i % tiers.len()]);
        let resp = server.request(req).unwrap().wait().unwrap();
        assert!(resp.error.is_none(), "request failed: {:?}", resp.error);
    }
    let metrics = server.metrics.clone();
    // shutdown joins the scheduler, which flushes the timed mesh events
    // into the tracer — the trace is only complete after this returns
    server.shutdown();
    let trace = tracer.to_chrome_json().to_string_pretty();
    let snap = MetricsSnapshot::new("obs_test").with_server(&metrics).to_string_pretty();
    Some((trace, snap))
}

#[test]
fn traced_server_run_exports_spans_and_collectives() {
    let Some((trace, snap)) = run_once() else { return };

    let doc = Value::parse(&trace).expect("trace must be valid JSON");
    assert_eq!(doc.get("displayTimeUnit").and_then(Value::as_str), Some("ms"));
    let events = doc.get("traceEvents").and_then(Value::as_arr).expect("traceEvents array");
    assert!(!events.is_empty());

    let mut req_spans = 0usize;
    let mut tiered = 0usize;
    let mut mesh_collectives = 0usize;
    let mut first_tokens = 0usize;
    for e in events {
        let name = e.get("name").and_then(Value::as_str).unwrap_or("");
        let cat = e.get("cat").and_then(Value::as_str).unwrap_or("");
        let has_dur = e.get("dur").is_some();
        if name.starts_with("req ") && has_dur {
            req_spans += 1;
            let tier =
                e.get("args").and_then(|a| a.get("tier")).and_then(Value::as_str);
            assert!(tier.is_some(), "request span missing tier attribute");
        }
        if e.get("args").and_then(|a| a.get("tier")).is_some() {
            tiered += 1;
        }
        if cat == "mesh" && (name == "all_reduce" || name == "reduce_into") {
            mesh_collectives += 1;
        }
        if name == "first_token" {
            first_tokens += 1;
        }
    }
    assert_eq!(req_spans, 3, "one lifecycle span per request");
    assert!(tiered >= 3, "tier attributes must survive export");
    assert!(mesh_collectives > 0, "mesh collective events missing from the trace");
    assert_eq!(first_tokens, 3, "one first_token instant per request");

    let sdoc = Value::parse(&snap).expect("snapshot must be valid JSON");
    assert!(MetricsSnapshot::is_snapshot_json(&sdoc));
    let flat = MetricsSnapshot::flatten(&sdoc);
    assert_eq!(flat.get("obs_test.server.requests_completed"), Some(&3.0));
    assert!(flat.keys().any(|k| k.starts_with("obs_test.server.tiers.")));
}

/// Satellite of the determinism story: two identical traced runs must
/// produce byte-identical artifacts end-to-end through the real Server —
/// threads, channels and all — because nothing in either export reads the
/// wall clock.
#[test]
fn identical_server_runs_export_identical_artifacts() {
    let Some((trace1, snap1)) = run_once() else { return };
    let (trace2, snap2) = run_once().unwrap();
    assert_eq!(trace1, trace2, "trace export must be byte-identical across runs");
    assert_eq!(snap1, snap2, "metrics snapshot must be byte-identical across runs");
}
