//! Cross-layer integration tests: the properties that tie the three layers
//! together. All tests no-op gracefully when `make artifacts` hasn't run.
//!
//! The central invariant: the *scoring* executor (single-device, per-delta
//! composition) and the *serving* executor (2-rank tensor-parallel mesh
//! with all-reduces) are two implementations of the same mathematics and
//! must agree numerically — for the sequential plan AND for LP pairs.

use truedepth::api::CompletionRequest;
use truedepth::config::{InterconnectConfig, ServerConfig};
use truedepth::coordinator::Server;
use truedepth::eval::ppl::eval_windows;
use truedepth::model::{transform, Scorer, ServingModel, Weights};
use truedepth::runtime::{Engine, Manifest};
use truedepth::text::corpus::DATA_SEED;
use truedepth::text::tokenizer;

fn setup() -> Option<(Manifest, Weights)> {
    let manifest = Manifest::load_default().ok()?;
    let cfg = manifest.model("td-small").ok()?.config.clone();
    Some((manifest, Weights::random(&cfg, 2026)))
}

fn no_net() -> InterconnectConfig {
    InterconnectConfig { enabled: false, ..Default::default() }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Scoring (single device) vs serving (TP mesh, 2 all-reduces/layer): the
/// sequential plan must produce identical last-token logits.
#[test]
fn scoring_and_tp_serving_agree_sequential() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let n = entry.config.n_layers;
    let plan = transform::sequential(n);

    let tokens: Vec<i32> = tokenizer::encode("the quiet river finds the stone", true, false);
    let engine = Engine::cpu().unwrap();
    let scorer = Scorer::new(&engine, entry, &weights, 32).unwrap();
    let padded = tokenizer::pad_to(&tokens, 32).unwrap();
    let logits = scorer.logits(&padded, &plan).unwrap();
    let v = entry.config.vocab;
    let last = tokens.len() - 1;
    let expect = &logits[last * v..(last + 1) * v];

    let serving = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
    let got = serving.prefill(0, &tokens).unwrap();
    let diff = max_abs_diff(expect, &got);
    assert!(diff < 2e-3, "seq scoring vs serving diverged: {diff}");
}

/// Same agreement for an LP plan: the mesh's split across two ranks plus
/// all-reduce must reproduce the scoring executor's PairLp numerics.
#[test]
fn scoring_and_lp_serving_agree() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let n = entry.config.n_layers;
    let plan = transform::pair_parallel(n, 2, 10, true);

    let tokens: Vec<i32> = tokenizer::encode("copy : abcd -> ", true, false);
    let engine = Engine::cpu().unwrap();
    let scorer = Scorer::new(&engine, entry, &weights, 32).unwrap();
    let padded = tokenizer::pad_to(&tokens, 32).unwrap();
    let logits = scorer.logits(&padded, &plan).unwrap();
    let v = entry.config.vocab;
    let last = tokens.len() - 1;
    let expect = &logits[last * v..(last + 1) * v];

    let serving = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
    let got = serving.prefill(0, &tokens).unwrap();
    let diff = max_abs_diff(expect, &got);
    assert!(diff < 2e-3, "LP scoring vs serving diverged: {diff}");
}

/// Decode with a KV cache must continue exactly where prefill left off:
/// prefill(t0..t_k) + decode(t_{k+1}) == prefill(t0..t_{k+1}).
#[test]
fn incremental_decode_matches_longer_prefill() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let cfg = entry.config.clone();
    let plan = transform::pair_parallel(cfg.n_layers, 4, 8, true);
    let serving = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();

    let full: Vec<i32> = tokenizer::encode("the tall wolf seeks", true, false);
    let k = full.len() - 1;

    // reference: prefill the whole sequence, read last logits
    let expect = serving.prefill(0, &full).unwrap();

    // incremental: prefill k tokens into slot 0, then decode token k
    let _ = serving.prefill(0, &full[..k]).unwrap();
    let s = cfg.slots;
    let mut tok = vec![0i32; s];
    let mut pos = vec![0i32; s];
    tok[0] = full[k];
    pos[0] = k as i32;
    let out = serving.decode_step(&tok, &pos).unwrap();
    let got = &out[..cfg.vocab];

    let diff = max_abs_diff(&expect, got);
    assert!(diff < 2e-3, "decode continuation diverged from prefill: {diff}");
}

/// Slot isolation: concurrent sequences in different slots must not bleed
/// into each other — decoding slot 0 must give the same logits whether or
/// not slot 1 holds a different sequence.
#[test]
fn kv_slots_are_isolated() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let cfg = entry.config.clone();
    let plan = transform::sequential(cfg.n_layers);
    let serving = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();

    let a: Vec<i32> = tokenizer::encode("the red fox", true, false);
    let b: Vec<i32> = tokenizer::encode("9 - 4 = ", true, false);
    let s = cfg.slots;

    // run A alone
    let _ = serving.prefill(0, &a).unwrap();
    let mut tok = vec![0i32; s];
    let mut pos = vec![0i32; s];
    tok[0] = 32;
    pos[0] = a.len() as i32;
    let alone = serving.decode_step(&tok, &pos).unwrap()[..cfg.vocab].to_vec();

    // run A in slot 0 with B active in slot 1
    let _ = serving.prefill(0, &a).unwrap();
    let _ = serving.prefill(1, &b).unwrap();
    tok[1] = 53;
    pos[1] = b.len() as i32;
    let together = serving.decode_step(&tok, &pos).unwrap()[..cfg.vocab].to_vec();

    let diff = max_abs_diff(&alone, &together);
    assert!(diff < 1e-4, "slot bleed: {diff}");
}

/// Full-stack serving determinism: same prompt through the server twice
/// (greedy) must give the same tokens, and LP vs sequential plans both
/// produce well-formed responses.
#[test]
fn server_greedy_is_deterministic_across_plans() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let n = entry.config.n_layers;
    for plan in [transform::sequential(n), transform::pair_parallel(n, 2, 10, true)] {
        let serving =
            ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();
        let server = Server::start(serving, &ServerConfig::default());
        let req = CompletionRequest::new("the calm ship").max_tokens(6);
        let r1 = server.request(req.clone()).unwrap().wait().unwrap();
        let r2 = server.request(req).unwrap().wait().unwrap();
        assert!(r1.error.is_none() && r2.error.is_none());
        assert_eq!(r1.tokens, r2.tokens, "greedy decode must be deterministic");
        assert_eq!(r1.generated_tokens(), 6);
        server.shutdown();
    }
}

/// The simulated interconnect must make LP cheaper per token than
/// sequential TP at equal workload (the paper's core claim, in miniature).
/// Asserts on the SimNet's *charged* (modelled) cost, which is
/// deterministic — wall-clock assertions here were flaky under load.
#[test]
fn lp_reduces_sync_cost_per_decode_step() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let cfg = entry.config.clone();
    let n = cfg.n_layers;
    let net = InterconnectConfig { alpha_s: 200e-6, beta_bytes_per_s: 25e9, enabled: true };

    let mut costs = vec![];
    for plan in [transform::sequential(n), transform::pair_parallel(n, 0, n, true)] {
        let serving =
            ServingModel::new(&manifest, "td-small", &weights, &plan, net.clone()).unwrap();
        let prompt: Vec<i32> = (0..16).map(|i| 97 + (i % 26)).collect();
        serving.prefill(0, &prompt).unwrap();
        let tok = vec![65i32; cfg.slots];
        let pos = vec![16i32; cfg.slots];
        serving.decode_step(&tok, &pos).unwrap(); // warm
        serving.mesh.metrics.reset();
        for _ in 0..3 {
            serving.decode_step(&tok, &pos).unwrap();
        }
        let (sync_ops, _, _, _) = serving.mesh.metrics.snapshot();
        let charged_ms = serving.mesh.metrics.modelled_sync_ms();
        costs.push((plan.effective_depth(), sync_ops, charged_ms));
    }
    let (d_seq, ops_seq, c_seq) = costs[0];
    let (d_lp, ops_lp, c_lp) = costs[1];
    assert_eq!(d_seq, n);
    assert_eq!(d_lp, n / 2);
    assert_eq!(ops_seq, 2 * ops_lp, "LP must halve the all-reduce count");
    assert!(
        c_lp < c_seq,
        "halved sync count must halve the charged α–β cost: lp {c_lp} ms vs seq {c_seq} ms"
    );
}

/// Tentpole regression: the resident-activation decode path must be
/// bit-identical to the pre-refactor host-round-trip path on a mixed
/// Tp/Lp plan (same executables, same reduction order — same floats),
/// with the all-reduce count unchanged (2 per stage) and host↔device
/// activation traffic collapsed from O(stages) to O(1) per token.
#[test]
fn resident_decode_is_bit_identical_to_host_reference() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let cfg = entry.config.clone();
    // mixed plan: Seq (Tp) stages outside the [4, 10) window, Lp pairs inside
    let plan = transform::pair_parallel(cfg.n_layers, 4, 10, true);
    let stages = plan.effective_depth();
    let serving = ServingModel::new(&manifest, "td-small", &weights, &plan, no_net()).unwrap();

    let a: Vec<i32> = tokenizer::encode("the red fox", true, false);
    let b: Vec<i32> = tokenizer::encode("9 - 4 = ", true, false);
    serving.prefill(0, &a).unwrap();
    serving.prefill(1, &b).unwrap();
    let s = cfg.slots;
    let mut tok = vec![0i32; s];
    let mut pos = vec![0i32; s];
    tok[0] = 32;
    pos[0] = a.len() as i32;
    tok[1] = 53;
    pos[1] = b.len() as i32;

    serving.mesh.metrics.reset();
    let resident = serving.decode_step(&tok, &pos).unwrap();
    let (ops_resident, _, _, _) = serving.mesh.metrics.snapshot();
    let host_resident = serving.mesh.metrics.host_transfers();

    // Same token at the same positions: the reference path rewrites the
    // same KV entries with the same values, so state stays consistent.
    serving.mesh.metrics.reset();
    let reference = serving.decode_step_host_reference(&tok, &pos).unwrap();
    let (ops_reference, _, _, _) = serving.mesh.metrics.snapshot();
    let host_reference = serving.mesh.metrics.host_transfers();

    assert_eq!(resident, reference, "resident path diverged from host reference");
    assert_eq!(ops_resident as usize, 2 * stages, "sync_ops must stay 2 per stage");
    assert_eq!(ops_resident, ops_reference, "all-reduce accounting must not change");
    // O(1) vs O(stages): tokens + positions in, embed shadow + logits out.
    assert_eq!(host_resident.in_ops, 1 + serving.mesh.ranks() as u64);
    assert_eq!(host_resident.out_ops, 2);
    assert!(
        host_reference.ops() >= 4 * stages as u64,
        "reference path should pay per-stage host traffic, got {host_reference:?}"
    );
}

/// Perplexity pipeline sanity on random weights: ppl ≈ vocab for an
/// untrained model (uniform predictions), for both executors' plans.
#[test]
fn random_model_ppl_is_near_uniform() {
    let Some((manifest, weights)) = setup() else { return };
    let entry = manifest.model("td-small").unwrap();
    let engine = Engine::cpu().unwrap();
    let scorer = Scorer::new(&engine, entry, &weights, 32).unwrap();
    let windows = eval_windows(32, 1, DATA_SEED);
    let plan = transform::sequential(entry.config.n_layers);
    let ppl = truedepth::eval::ppl::perplexity(&scorer, &plan, &windows).unwrap();
    let v = entry.config.vocab as f64;
    assert!(ppl > v * 0.2 && ppl < v * 5.0, "untrained ppl {ppl} vs vocab {v}");
}
