//! End-to-end acceptance for the multi-replica cluster subsystem: the
//! deterministic load harness drives a real lockstep cluster (real
//! schedulers, real simulated meshes, real paged KV) and the tests pin
//! the ISSUE's cluster guarantees:
//!
//! * same (scenario, seed) → byte-identical metrics snapshot AND
//!   byte-identical per-replica Chrome traces across runs; distinct
//!   seeds diverge;
//! * a replica killed mid-run loses ZERO requests — displaced work
//!   migrates to the sibling and every arrival still gets a terminal
//!   response, with failover/respawn/migration counters reconciling;
//! * routed results are bit-identical per request to a single-replica
//!   oracle run of the same trace (routing changes *where*, never
//!   *what*);
//! * session-affine multi-turn traffic reuses shared-prefix KV locally
//!   (`kv.prefix_hits > 0` under `--paged`-style serving).
//!
//! No-ops gracefully when `make artifacts` hasn't run (same convention
//! as `tests/integration.rs`).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use truedepth::cluster::{loadgen, Cluster, FaultPlan, LoadTrace, ModelFactory, Scenario};
use truedepth::harness::no_net;
use truedepth::model::{ServingModel, Weights};
use truedepth::obs::Tracer;
use truedepth::runtime::Manifest;

/// Artifact-gated replica factory over seeded weights: every replica is
/// bit-identical, which is what makes migration replay and the oracle
/// comparison exact. `paged` opts into the paged KV + prefix index.
fn factory(paged: bool) -> Option<ModelFactory> {
    let manifest = Manifest::load_default().ok()?;
    let cfg = manifest.model("td-small").ok()?.config.clone();
    // probe once so construction failures (or missing kv_pages when
    // paging is requested) skip the test instead of panicking
    {
        let weights = Weights::random(&cfg, 11);
        let mut m =
            ServingModel::from_manifest(&manifest, "td-small", &weights, no_net()).ok()?;
        if paged {
            m.enable_paging().ok()?;
        }
    }
    Some(Box::new(move |_i| {
        let weights = Weights::random(&cfg, 11);
        let mut m = ServingModel::from_manifest(&manifest, "td-small", &weights, no_net())?;
        if paged {
            m.enable_paging()?;
        }
        Ok(m)
    }))
}

/// One full loadgen replay on a fresh 2-replica cluster; returns the
/// metrics snapshot and per-replica Chrome traces as strings.
fn run_once(scenario: Scenario, seed: u64, n: usize) -> Option<(String, Vec<String>)> {
    let factory = factory(false)?;
    let tracers: Vec<_> = (0..2).map(|_| Arc::new(Tracer::new())).collect();
    let mut cluster =
        Cluster::with_tracers("td-small", factory, 2, 64, Some(tracers.clone())).unwrap();
    let tiers = cluster.models_response().models[0].tiers.clone();
    let trace = LoadTrace::generate(scenario, seed, n, &tiers);
    let report = loadgen::run(&mut cluster, &trace, None).unwrap();
    assert_eq!(report.failed() + report.rejected(), 0, "clean run expected");
    let snap = cluster.snapshot("loadtest").to_string_pretty();
    let traces =
        tracers.iter().map(|t| t.to_chrome_json().to_string_pretty()).collect();
    Some((snap, traces))
}

/// Satellite + tentpole acceptance: the whole observable output of a
/// cluster replay — the metrics snapshot (cluster section, per-replica
/// sections, modelled percentiles) and every replica's trace — is a pure
/// function of (scenario, seed).
#[test]
fn same_seed_replays_are_byte_identical_and_seeds_diverge() {
    let Some((snap_a, traces_a)) = run_once(Scenario::Mixed, 42, 10) else { return };
    let Some((snap_b, traces_b)) = run_once(Scenario::Mixed, 42, 10) else { return };
    assert_eq!(snap_a, snap_b, "same seed must export a byte-identical snapshot");
    assert_eq!(traces_a.len(), 2);
    for (i, (a, b)) in traces_a.iter().zip(&traces_b).enumerate() {
        assert_eq!(a, b, "replica {i} trace must be byte-identical across runs");
        assert!(a.len() > 2, "replica {i} trace must not be empty");
    }
    let Some((snap_c, _)) = run_once(Scenario::Mixed, 43, 10) else { return };
    assert_ne!(snap_a, snap_c, "distinct seeds must produce distinct snapshots");
}

/// Tentpole acceptance: kill a replica while it holds queued + in-flight
/// work, respawn it later — zero requests lost, the displaced work
/// migrates, and the counters reconcile with the report.
#[test]
fn replica_kill_mid_run_loses_zero_requests() {
    let Some(factory) = factory(false) else { return };
    let mut cluster = Cluster::new("td-small", factory, 2, 64).unwrap();
    let tiers = cluster.models_response().models[0].tiers.clone();
    // flood: every arrival lands before the fault, so replica 0 is
    // guaranteed to hold work (the router sends it request 0) when fenced
    let trace = LoadTrace::generate(Scenario::Flood, 7, 10, &tiers);
    let fault = FaultPlan { replica: 0, fail_at_step: 2, respawn_at_step: Some(40) };
    let report = loadgen::run(&mut cluster, &trace, Some(&fault)).unwrap();
    assert_eq!(report.rejected(), 0, "nothing may be shed at queue depth 64");
    assert_eq!(report.failed(), 0, "a fenced replica with a healthy sibling loses nothing");
    assert_eq!(report.completed(), trace.arrivals.len());
    let m = &cluster.metrics;
    assert_eq!(m.failovers.load(Ordering::Relaxed), 1);
    assert_eq!(m.respawns.load(Ordering::Relaxed), 1);
    assert!(
        m.migrations.load(Ordering::Relaxed) >= 1,
        "displaced work must migrate to the sibling"
    );
    // reconciliation: every submitted request has exactly one terminal
    // response, across both the report and the cluster counters
    assert_eq!(m.submitted.load(Ordering::Relaxed) as usize, trace.arrivals.len());
    assert_eq!(m.completed.load(Ordering::Relaxed) as usize, trace.arrivals.len());
    assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    assert!(cluster.is_healthy(0), "replica 0 must be back after respawn");
}

/// Tentpole acceptance: routing is placement-only. Every request decodes
/// to the same tokens/text whether it runs on a 2-replica cluster or a
/// single-replica oracle, because replicas are bit-identical and greedy
/// decode is deterministic per request id.
#[test]
fn routed_results_are_bit_identical_to_a_single_replica_oracle() {
    let Some(f_oracle) = factory(false) else { return };
    let Some(f_cluster) = factory(false) else { return };
    let mut oracle = Cluster::new("td-small", f_oracle, 1, 64).unwrap();
    let mut cluster = Cluster::new("td-small", f_cluster, 2, 64).unwrap();
    let tiers = cluster.models_response().models[0].tiers.clone();
    let trace = LoadTrace::generate(Scenario::Steady, 5, 8, &tiers);
    let r_oracle = loadgen::run(&mut oracle, &trace, None).unwrap();
    let r_cluster = loadgen::run(&mut cluster, &trace, None).unwrap();
    // the cluster actually exercised both replicas — otherwise this test
    // degenerates into oracle-vs-oracle
    let routed = cluster.metrics.routed_per_replica();
    assert!(routed.iter().all(|&c| c > 0), "both replicas must serve: {routed:?}");
    for (i, (a, b)) in r_oracle.responses.iter().zip(&r_cluster.responses).enumerate() {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert!(a.error.is_none(), "oracle arrival {i}: {:?}", a.error);
        assert!(b.error.is_none(), "cluster arrival {i}: {:?}", b.error);
        assert_eq!(a.tokens, b.tokens, "arrival {i}: tokens diverge from the oracle");
        assert_eq!(a.text, b.text, "arrival {i}: text diverges from the oracle");
        assert_eq!(a.tier, b.tier, "arrival {i}: tier diverges from the oracle");
    }
}

/// Session affinity keeps multi-turn shared-prefix reuse local: under
/// paged serving, later turns of a session land on the replica that
/// already holds the session's prefix blocks, so the paged-KV prefix
/// index scores hits.
#[test]
fn session_affine_multiturn_traffic_reuses_prefix_kv() {
    let Some(factory) = factory(true) else { return };
    let mut cluster = Cluster::new("td-small", factory, 2, 64).unwrap();
    let tiers = cluster.models_response().models[0].tiers.clone();
    let trace = LoadTrace::generate(Scenario::MultiTurn, 3, 8, &tiers);
    assert!(
        trace.arrivals.iter().all(|a| a.session.is_some()),
        "multiturn arrivals must carry session keys"
    );
    let report = loadgen::run(&mut cluster, &trace, None).unwrap();
    assert_eq!(report.failed() + report.rejected(), 0);
    assert!(
        cluster.metrics.affinity_hits.load(Ordering::Relaxed) > 0,
        "later turns must hit the affinity map"
    );
    let hits: u64 = (0..cluster.replica_count())
        .map(|i| cluster.replica_metrics(i).kv_prefix_hits.load(Ordering::Relaxed))
        .sum();
    assert!(hits > 0, "shared session prefixes must score paged-KV prefix hits");
}
